//! Lowering of a [`ScenarioSpec`] onto the batched evaluation hot path.
//!
//! Every study kind follows the same shape as the hand-tuned figure
//! drivers in [`crate::coordinator::sweep`]: enumerate the full
//! (workload, cluster, options) job list up front, resolve it concurrently
//! through [`Coordinator::derive_batch`], make **exactly one**
//! [`Coordinator::evaluate_inputs`] call (normalization baselines ride in
//! the same batch), then render a [`FigureData`]. The built-in registry
//! specs are verified cell-for-cell against the legacy drivers by
//! `tests/scenario_roundtrip.rs` — the lowering here must stay
//! numerically identical to them.

use crate::analytical::{goodput, TrainingBreakdown};
use crate::config::ClusterConfig;
use crate::coordinator::sweep::{dlrm_nodes_per_instance, SweepSpec};
use crate::coordinator::{Coordinator, GridSweep};
use crate::error::{Error, Result};
use crate::model::inputs::EvalOptions;
use crate::network::CollectiveImpl;
use crate::optimizer::checkpoint::Checkpoint;
use crate::optimizer::{
    AxisSpec, Branch, Objective, Optimizer, Outcome, SearchExec,
};
use crate::resilience::{checkpoint_bandwidth, FaultModel};
use crate::util::cancel::{CancelToken, Deadline, RunControl};
use crate::parallel::{
    model_state_bytes, pipeline_footprint_per_node, PipeSchedule, Strategy,
    TierMapping, ZeroStage,
};
use crate::report::FigureData;
use crate::util::units::gb;
use crate::workload::{CommScope, Workload};

use std::path::Path;

use super::spec::{
    collective_name, Content, Normalize, ScenarioSpec, StrategyAxis, Study,
    WorkloadSpec,
};

/// Execute a scenario on a coordinator, producing the result grid.
pub fn run(spec: &ScenarioSpec, coord: &Coordinator) -> Result<FigureData> {
    run_controlled(spec, coord, &RunControl::unbounded())
}

/// [`run`] with a caller-supplied [`RunControl`]: every coordinator
/// batch call polls it at its safe boundaries, so a cancelled token or
/// an expired deadline stops the study between batches with a
/// structured [`Error::Cancelled`] / [`Error::Deadline`] — never
/// mid-evaluation. This is the serve layer's request path: one shared
/// coordinator, one control per request.
///
/// `Optimize` studies route through [`run_optimize`] unchanged here —
/// callers that need per-request cancellation *and* the partial
/// `Outcome` contract (best-so-far table + `PARTIAL` note) should call
/// [`run_optimize_exec`] with the token/deadline on [`ExecOverrides`],
/// which is what the serve layer does.
pub fn run_controlled(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    control: &RunControl,
) -> Result<FigureData> {
    let mut fig = match &spec.study {
        Study::Footprint { strategies } => run_footprint(spec, strategies)?,
        Study::Grid {
            strategies,
            em_bandwidths_gbps,
            em_capacities_gb,
            collectives,
            zero_stages,
            baseline,
        } => run_grid(
            spec,
            coord,
            &GridAxes {
                strategies: strategies.resolve(spec.cluster.n_nodes)?,
                em_bandwidths_gbps,
                em_capacities_gb,
                collectives,
                zero_stages,
                baseline: *baseline,
            },
            control,
        )?,
        Study::ComputeScaling {
            strategy,
            scales,
            em_bandwidths_gbps,
        } => run_compute_scaling(
            spec,
            coord,
            *strategy,
            scales,
            em_bandwidths_gbps,
            control,
        )?,
        Study::NetworkScaling {
            strategies,
            intra_factors,
            inter_factors,
        } => run_network_scaling(
            spec,
            coord,
            strategies,
            intra_factors,
            inter_factors,
            control,
        )?,
        Study::NetworkRebalance { strategies, ratios } => {
            run_network_rebalance(spec, coord, strategies, ratios, control)?
        }
        Study::ClusterSize {
            sizes,
            em_bandwidth_gbps,
        } => run_cluster_size(spec, coord, sizes, *em_bandwidth_gbps, control)?,
        Study::Packing {
            instances,
            packings,
            em_bandwidths_gbps,
        } => run_packing(
            spec,
            coord,
            *instances,
            packings,
            em_bandwidths_gbps,
            control,
        )?,
        Study::Optimize { .. } => {
            control.check("scenario run")?;
            run_optimize(spec, coord)?.0
        }
        Study::Resilience {
            strategies,
            mtbf_hours,
            em_bandwidth_gbps,
            deadline_s,
        } => run_resilience(
            spec,
            coord,
            strategies,
            mtbf_hours,
            *em_bandwidth_gbps,
            *deadline_s,
            control,
        )?,
        Study::Pipeline {
            mp,
            pps,
            microbatch_counts,
            schedules,
        } => run_pipeline(
            spec,
            coord,
            *mp,
            pps,
            microbatch_counts,
            schedules,
            control,
        )?,
        Study::TierMapping {
            strategies,
            mappings,
        } => run_tier_mapping(spec, coord, strategies, mappings, control)?,
        Study::ClusterCompare {
            clusters,
            dlrm,
            instances,
            partition,
        } => run_cluster_compare(
            spec,
            coord,
            clusters,
            dlrm,
            *instances,
            *partition,
            control,
        )?,
    };
    apply_columns_override(spec, &mut fig)?;
    Ok(fig)
}

/// Apply `[output].columns` to a rendered figure, validating the width.
/// Idempotent — `run_optimize` applies it itself (the CLI calls it
/// directly, bypassing [`run`]), and [`run`] applies it to every study.
fn apply_columns_override(
    spec: &ScenarioSpec,
    fig: &mut FigureData,
) -> Result<()> {
    if let Some(cols) = &spec.output.columns {
        if cols.len() != fig.columns.len() {
            return Err(Error::Config(format!(
                "scenario '{}': columns override has {} entries, grid has {}",
                spec.name,
                cols.len(),
                fig.columns.len()
            )));
        }
        fig.columns = cols.clone();
    }
    Ok(())
}

// ---- shared helpers -------------------------------------------------------

fn eval_opts(spec: &ScenarioSpec) -> EvalOptions {
    let o = &spec.options;
    EvalOptions {
        zero_stage: o.zero_stage,
        ignore_capacity: o.infinite_memory,
        em_frac_override: o.em_frac,
        footprint_override: None,
        overlap_wg: o.overlap_wg,
        collective_impl: o.collective,
        microbatches: o.microbatches,
        pipe_schedule: o.schedule,
        tier_mapping: o.tier_mapping,
    }
}

fn build_for(w: &WorkloadSpec, s: &Strategy) -> Result<Workload> {
    match w {
        WorkloadSpec::Transformer(t) => t.build(s),
        WorkloadSpec::Gemm(g) => g.build(s),
        WorkloadSpec::Dlrm(_) => Err(Error::Config(
            "scenario: a strategy sweep needs a transformer or gemm \
             workload; use cluster-size/packing/cluster-compare studies \
             for DLRM"
                .into(),
        )),
    }
}

fn workload_total_params(w: &WorkloadSpec) -> f64 {
    match w {
        WorkloadSpec::Transformer(t) => t.total_params(),
        WorkloadSpec::Dlrm(d) => d.total_params(),
        WorkloadSpec::Gemm(g) => g.total_params(),
    }
}

fn require_dlrm(spec: &ScenarioSpec) -> Result<&crate::workload::dlrm::Dlrm> {
    match &spec.workload {
        WorkloadSpec::Dlrm(d) => Ok(d),
        _ => Err(Error::Config(format!(
            "scenario '{}': the {} study requires a dlrm workload",
            spec.name,
            spec.study.kind()
        ))),
    }
}

fn figure(spec: &ScenarioSpec, default_row_label: &str) -> FigureData {
    FigureData {
        id: spec.name.clone(),
        title: spec.title.clone(),
        row_label: spec
            .output
            .row_label
            .clone()
            .unwrap_or_else(|| default_row_label.to_string()),
        columns: Vec::new(),
        rows: Vec::new(),
        notes: spec.output.notes.clone(),
    }
}

/// The six breakdown column headers + total (paper Fig. 8a order).
const BREAKDOWN_COLS: [&str; 7] = [
    "FP_Compute",
    "FP_Exp_Comm",
    "IG_Compute",
    "IG_Exp_Comm",
    "WG_Compute",
    "WG_Exp_Comm",
    "Total_s",
];

/// Render breakdown rows into `fig`: the six phase columns + `Total_s`,
/// an optional normalization column (named `first_col` for
/// [`Normalize::First`]), and an optional `Footprint_GB` column fed from
/// per-row footprints in bytes. Shared by the grid and cluster-size
/// studies — their output must never drift apart.
///
/// Pipeline-parallel rows carry two extra terms (`bubble`,
/// `pp_exposed_comm`) that the six phase columns do not cover; when any
/// row has them, `Bubble` and `PP_Exp_Comm` columns are inserted before
/// `Total_s` so the components always sum to the total. On the 2D slice
/// both terms are exactly zero and the layout is bit-for-bit the
/// pre-pipeline one.
fn render_breakdown(
    fig: &mut FigureData,
    evals: &[TrainingBreakdown],
    labels: Vec<String>,
    footprints: Option<Vec<f64>>,
    normalize: Normalize,
    first_col: &str,
) {
    let pipeline = evals
        .iter()
        .any(|b| b.bubble != 0.0 || b.pp_exposed_comm != 0.0);
    fig.columns = BREAKDOWN_COLS[..6].iter().map(|s| s.to_string()).collect();
    if pipeline {
        fig.columns.push("Bubble".into());
        fig.columns.push("PP_Exp_Comm".into());
    }
    fig.columns.push("Total_s".into());
    let norm = match normalize {
        Normalize::None => None,
        Normalize::Best => {
            fig.columns.push("Norm_to_best".into());
            Some(
                evals
                    .iter()
                    .map(|b| b.total())
                    .fold(f64::INFINITY, f64::min),
            )
        }
        Normalize::First => {
            fig.columns.push(first_col.to_string());
            evals.first().map(|b| b.total())
        }
    };
    if footprints.is_some() {
        fig.columns.push("Footprint_GB".into());
    }
    for (i, (label, b)) in labels.into_iter().zip(evals).enumerate() {
        let mut vals = b.as_array().to_vec();
        if pipeline {
            vals.push(b.bubble);
            vals.push(b.pp_exposed_comm);
        }
        vals.push(b.total());
        if let Some(base) = norm {
            vals.push(b.total() / base);
        }
        if let Some(fps) = &footprints {
            vals.push(fps[i] / gb(1.0));
        }
        fig.rows.push((label, vals));
    }
}

/// Scale DP-scope WG collective payloads by the stage's communication
/// multiplier (ZeRO-3's 1.5x parameter all-gather overhead).
fn apply_zero_comm(mut w: Workload, stage: ZeroStage) -> Workload {
    for l in &mut w.layers {
        if l.comm_wg.scope == CommScope::Dp {
            l.comm_wg.bytes *= stage.comm_multiplier();
        }
    }
    w
}

// ---- footprint ------------------------------------------------------------

fn run_footprint(
    spec: &ScenarioSpec,
    strategies: &super::spec::StrategyAxis,
) -> Result<FigureData> {
    let psi = workload_total_params(&spec.workload);
    let mut fig = figure(spec, "(MP, DP)");
    fig.columns = ZeroStage::ALL
        .iter()
        .map(|s| s.label().to_string())
        .collect();
    for s in strategies.resolve(spec.cluster.n_nodes)? {
        // PP shards the model-state shard further; /1 is exact on the 2D
        // slice, so the pinned fig6 cells are untouched.
        let vals: Vec<f64> = ZeroStage::ALL
            .iter()
            .map(|&st| {
                model_state_bytes(psi, s.mp, s.dp, st) / s.pp as f64 / gb(1.0)
            })
            .collect();
        fig.rows.push((s.label(), vals));
    }
    Ok(fig)
}

// ---- grid -----------------------------------------------------------------

struct GridAxes<'a> {
    strategies: Vec<Strategy>,
    em_bandwidths_gbps: &'a [f64],
    em_capacities_gb: &'a [f64],
    collectives: &'a [CollectiveImpl],
    zero_stages: &'a [ZeroStage],
    baseline: Option<Strategy>,
}

/// One evaluated grid point with everything rendering needs.
struct GridRow {
    strategy: Strategy,
    stage: ZeroStage,
    /// Expanded-memory bandwidth of the point, GB/s.
    em_bw_gbps: Option<f64>,
    /// Expanded-memory capacity of the point, GB.
    em_cap_gb: Option<f64>,
    collective: CollectiveImpl,
    /// Per-node footprint of the point's (workload, stage), bytes.
    footprint: f64,
}

fn run_grid(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    axes: &GridAxes<'_>,
    control: &RunControl,
) -> Result<FigureData> {
    let opts0 = eval_opts(spec);
    let cluster = &spec.cluster;
    let explicit_zero = !axes.zero_stages.is_empty();
    let explicit_bw = !axes.em_bandwidths_gbps.is_empty();
    let explicit_cap = !axes.em_capacities_gb.is_empty();
    let explicit_coll = !axes.collectives.is_empty();
    let zaxis: Vec<ZeroStage> = if explicit_zero {
        axes.zero_stages.to_vec()
    } else {
        vec![opts0.zero_stage]
    };
    let coll_axis: Vec<CollectiveImpl> = if explicit_coll {
        axes.collectives.to_vec()
    } else {
        vec![opts0.collective_impl]
    };
    let em_bws: Vec<f64> = axes.em_bandwidths_gbps.iter().map(|&b| gb(b)).collect();
    let em_caps: Vec<f64> = axes.em_capacities_gb.iter().map(|&c| gb(c)).collect();

    // Resolve the content and validate its shape against the axes BEFORE
    // deriving/evaluating anything — a malformed spec must not pay for
    // the full sweep first.
    let content = match spec.output.content {
        Content::Auto if axes.baseline.is_some() => Content::Speedup,
        Content::Auto => Content::Breakdown,
        c => c,
    };
    match content {
        Content::Speedup => {
            if axes.baseline.is_none() {
                return Err(Error::Config(format!(
                    "scenario '{}': speedup content requires study.baseline",
                    spec.name
                )));
            }
            if !explicit_bw || explicit_cap || explicit_coll || explicit_zero
            {
                return Err(Error::Config(format!(
                    "scenario '{}': speedup pivots on em_bandwidths_gbps \
                     and supports no other grid axis",
                    spec.name
                )));
            }
        }
        Content::CollectiveContrast => {
            if !explicit_coll
                || coll_axis.len() != 2
                || explicit_bw
                || explicit_cap
                || explicit_zero
            {
                return Err(Error::Config(format!(
                    "scenario '{}': collective-contrast requires exactly \
                     two collectives and no other grid axis",
                    spec.name
                )));
            }
        }
        Content::ZeroTable => {
            if !explicit_zero || explicit_bw || explicit_cap || explicit_coll
            {
                return Err(Error::Config(format!(
                    "scenario '{}': zero-table requires a zero_stages axis \
                     and no other grid axis",
                    spec.name
                )));
            }
        }
        _ => {}
    }

    let mut specs: Vec<SweepSpec> = Vec::new();
    let mut points: Vec<GridRow> = Vec::new();
    let base_offset = match axes.baseline {
        Some(b) => {
            specs.push((
                build_for(&spec.workload, &b)?,
                cluster.clone(),
                opts0,
            ));
            1
        }
        None => 0,
    };
    for s in &axes.strategies {
        let w0 = build_for(&spec.workload, s)?;
        for &stage in &zaxis {
            let w = if explicit_zero {
                apply_zero_comm(w0.clone(), stage)
            } else {
                w0.clone()
            };
            let fp = pipeline_footprint_per_node(
                &w,
                stage,
                opts0.pipe_schedule,
                opts0.microbatches,
            );
            let o = EvalOptions {
                zero_stage: stage,
                ..opts0
            };
            let mut g = GridSweep::new(vec![*s]);
            if explicit_bw {
                g = g.em_bandwidths(&em_bws);
            }
            if explicit_cap {
                g = g.em_capacities(&em_caps);
            }
            g = g.collective_impls(&coll_axis);
            for p in g.points() {
                points.push(GridRow {
                    strategy: *s,
                    stage,
                    em_bw_gbps: p.em_bandwidth.map(|b| b / 1e9),
                    em_cap_gb: p.em_capacity.map(|c| c / 1e9),
                    collective: p.collective_impl,
                    footprint: fp,
                });
            }
            specs.extend(g.specs(cluster, &o, |_| Ok(w.clone()))?);
        }
    }

    let inputs = coord.derive_batch_controlled(specs, control)?;
    let evals = coord.evaluate_inputs_controlled(&inputs, control)?;
    let grid_evals = &evals[base_offset..];

    let label_of = |p: &GridRow| {
        let mut l = p.strategy.label();
        if explicit_zero {
            l = format!("{l} {}", p.stage.label());
        }
        if let Some(bw) = p.em_bw_gbps {
            if explicit_bw {
                l = format!("{l} EM@{bw:.0}GB/s");
            }
        }
        if let Some(cap) = p.em_cap_gb {
            if explicit_cap {
                l = format!("{l} cap{cap:.0}GB");
            }
        }
        if explicit_coll {
            l = format!("{l} {}", collective_name(p.collective));
        }
        l
    };

    let mut fig = figure(spec, "(MP, DP)");
    match content {
        Content::Breakdown => {
            let labels = points.iter().map(&label_of).collect();
            let footprints = spec
                .output
                .footprint
                .then(|| points.iter().map(|p| p.footprint).collect());
            render_breakdown(
                &mut fig,
                grid_evals,
                labels,
                footprints,
                spec.output.normalize,
                "Norm_to_first",
            );
        }
        Content::Share => {
            fig.columns =
                vec!["Compute_frac".into(), "Exp_Comm_frac".into()];
            for (p, b) in points.iter().zip(grid_evals) {
                let compute = b.compute();
                let comm = b.exposed_comm();
                let total = compute + comm;
                fig.rows.push((
                    label_of(p),
                    vec![compute / total, comm / total],
                ));
            }
        }
        Content::Speedup => {
            let baseline = evals[0].total();
            let width = axes.em_bandwidths_gbps.len();
            fig.columns = axes
                .em_bandwidths_gbps
                .iter()
                .map(|b| format!("{b:.0}GB/s"))
                .collect();
            for (i, s) in axes.strategies.iter().enumerate() {
                let vals: Vec<f64> = (0..width)
                    .map(|j| baseline / grid_evals[i * width + j].total())
                    .collect();
                fig.rows.push((s.label(), vals));
            }
        }
        Content::CollectiveContrast => {
            let short = |c: CollectiveImpl| match c {
                CollectiveImpl::LogicalRing => "ring",
                CollectiveImpl::Hierarchical => "hier",
            };
            let (a, b) = (short(coll_axis[0]), short(coll_axis[1]));
            fig.columns = vec![
                format!("{a}_total_s"),
                format!("{b}_total_s"),
                format!("{a}/{b}"),
            ];
            for (i, s) in axes.strategies.iter().enumerate() {
                let ta = grid_evals[i * 2].total();
                let tb = grid_evals[i * 2 + 1].total();
                fig.rows.push((s.label(), vec![ta, tb, ta / tb]));
            }
        }
        Content::ZeroTable => {
            fig.columns = vec![
                "Footprint_GB".into(),
                "Total_s".into(),
                "WG_Exp_Comm_s".into(),
            ];
            for (p, b) in points.iter().zip(grid_evals) {
                fig.rows.push((
                    format!("{} {}", p.strategy.label(), p.stage.label()),
                    vec![p.footprint / gb(1.0), b.total(), b.wg_exposed_comm],
                ));
            }
        }
        Content::Auto => unreachable!("Auto resolved above"),
    }
    Ok(fig)
}

// ---- compute scaling (Fig. 10 shape) --------------------------------------

fn run_compute_scaling(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    strategy: Strategy,
    scales: &[f64],
    em_bandwidths_gbps: &[f64],
    control: &RunControl,
) -> Result<FigureData> {
    let base_cluster = &spec.cluster;
    let opts = eval_opts(spec);
    let w = build_for(&spec.workload, &strategy)?;
    let fp = pipeline_footprint_per_node(
        &w,
        opts.zero_stage,
        opts.pipe_schedule,
        opts.microbatches,
    );
    let need = (fp - base_cluster.node.local.capacity).max(0.0);
    let base_scale = scales.iter().position(|&x| x == 1.0).ok_or_else(|| {
        Error::Config(format!(
            "scenario '{}': compute-scaling scales must include 1.0",
            spec.name
        ))
    })?;
    if em_bandwidths_gbps.is_empty() {
        return Err(Error::Config(format!(
            "scenario '{}': compute-scaling requires em_bandwidths_gbps",
            spec.name
        )));
    }

    let mut specs: Vec<SweepSpec> =
        Vec::with_capacity(scales.len() * em_bandwidths_gbps.len());
    for &sc in scales {
        for &bw in em_bandwidths_gbps {
            let node = base_cluster
                .node
                .scale_compute(sc)
                .with_expanded(need, gb(bw));
            specs.push((w.clone(), base_cluster.with_node(node), opts));
        }
    }
    let inputs = coord.derive_batch_controlled(specs, control)?;
    let evals = coord.evaluate_inputs_controlled(&inputs, control)?;

    let width = em_bandwidths_gbps.len();
    let baseline = evals[base_scale * width + (width - 1)].total();
    let mut fig = figure(spec, "node compute");
    fig.columns = em_bandwidths_gbps
        .iter()
        .map(|b| format!("EM@{b:.0}GB/s"))
        .collect();
    for (i, sc) in scales.iter().enumerate() {
        fig.rows.push((
            format!("compute x{sc}"),
            (0..width)
                .map(|j| evals[i * width + j].total() / baseline)
                .collect(),
        ));
    }
    Ok(fig)
}

// ---- network scaling (Fig. 11 shape) --------------------------------------

fn run_network_scaling(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    strategies: &[Strategy],
    intra_factors: &[f64],
    inter_factors: &[f64],
    control: &RunControl,
) -> Result<FigureData> {
    let base_cluster = &spec.cluster;
    let opts = eval_opts(spec);
    let block = 1 + intra_factors.len() * inter_factors.len();
    let mut specs: Vec<SweepSpec> =
        Vec::with_capacity(strategies.len() * block);
    for s in strategies {
        let w = build_for(&spec.workload, s)?;
        specs.push((w.clone(), base_cluster.clone(), opts));
        for &fi in intra_factors {
            for &fx in inter_factors {
                specs.push((
                    w.clone(),
                    base_cluster.scale_network(fi, fx),
                    opts,
                ));
            }
        }
    }
    let inputs = coord.derive_batch_controlled(specs, control)?;
    let evals = coord.evaluate_inputs_controlled(&inputs, control)?;

    let mut fig = figure(spec, "config / intra factor");
    fig.columns = inter_factors
        .iter()
        .map(|f| format!("inter x{f}"))
        .collect();
    for (ci, s) in strategies.iter().enumerate() {
        let base = evals[ci * block].total();
        for (i, fi) in intra_factors.iter().enumerate() {
            fig.rows.push((
                format!("{} intra x{fi}", s.label()),
                (0..inter_factors.len())
                    .map(|j| {
                        base / evals
                            [ci * block + 1 + i * inter_factors.len() + j]
                            .total()
                    })
                    .collect(),
            ));
        }
    }
    Ok(fig)
}

// ---- network rebalancing (Fig. 12 shape) ----------------------------------

fn run_network_rebalance(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    strategies: &[Strategy],
    ratios: &[f64],
    control: &RunControl,
) -> Result<FigureData> {
    let base_cluster = &spec.cluster;
    let opts = eval_opts(spec);
    let nc = strategies.len();
    let mut specs: Vec<SweepSpec> =
        Vec::with_capacity(nc * (1 + ratios.len()));
    for s in strategies {
        specs.push((
            build_for(&spec.workload, s)?,
            base_cluster.clone(),
            opts,
        ));
    }
    for &r in ratios {
        let cluster = base_cluster.rebalance_network(r)?;
        for s in strategies {
            specs.push((
                build_for(&spec.workload, s)?,
                cluster.clone(),
                opts,
            ));
        }
    }
    let inputs = coord.derive_batch_controlled(specs, control)?;
    let evals = coord.evaluate_inputs_controlled(&inputs, control)?;

    let mut fig = figure(spec, "inter:intra ratio");
    fig.columns = strategies.iter().map(|s| s.label()).collect();
    for (ri, r) in ratios.iter().enumerate() {
        let vals: Vec<f64> = (0..nc)
            .map(|ci| evals[ci].total() / evals[nc + ri * nc + ci].total())
            .collect();
        fig.rows.push((format!("1:{r}"), vals));
    }
    Ok(fig)
}

// ---- DLRM cluster sizing (Fig. 13a shape) ---------------------------------

fn run_cluster_size(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    sizes: &[usize],
    em_bandwidth_gbps: Option<f64>,
    control: &RunControl,
) -> Result<FigureData> {
    let d = require_dlrm(spec)?;
    if sizes.is_empty() {
        return Err(Error::Config(format!(
            "scenario '{}': cluster-size requires at least one size",
            spec.name
        )));
    }
    let base_opts = eval_opts(spec);
    let mut footprints = Vec::with_capacity(sizes.len());
    let mut specs: Vec<SweepSpec> = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let w = d.build(n)?;
        let fp = d.footprint_per_node(n);
        let opts = EvalOptions {
            footprint_override: Some(fp),
            ..base_opts
        };
        let mut cluster = spec.cluster.with_n_nodes(n);
        let need = (fp - cluster.node.local.capacity).max(0.0);
        if need > 0.0 {
            let bw = em_bandwidth_gbps.ok_or_else(|| {
                Error::Config(format!(
                    "scenario '{}': the {}-node shard spills but no \
                     em_bandwidth_gbps is set",
                    spec.name, n
                ))
            })?;
            cluster.node = cluster.node.with_expanded(need, gb(bw));
        }
        footprints.push(fp);
        specs.push((w, cluster, opts));
    }
    let inputs = coord.derive_batch_controlled(specs, control)?;
    let evals = coord.evaluate_inputs_controlled(&inputs, control)?;

    let mut fig = figure(spec, "cluster");
    render_breakdown(
        &mut fig,
        &evals,
        sizes.iter().map(|n| format!("{n} nodes")).collect(),
        spec.output.footprint.then_some(footprints),
        spec.output.normalize,
        &format!("Norm_to_{}", sizes[0]),
    );
    Ok(fig)
}

// ---- DLRM packing (Fig. 13b shape) ----------------------------------------

fn run_packing(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    instances: f64,
    packings: &[usize],
    em_bandwidths_gbps: &[f64],
    control: &RunControl,
) -> Result<FigureData> {
    let d = require_dlrm(spec)?;
    let base_cluster = &spec.cluster;
    let total_nodes = base_cluster.n_nodes;
    let base_opts = eval_opts(spec);
    let width = em_bandwidths_gbps.len();
    if width == 0 || packings.is_empty() {
        return Err(Error::Config(format!(
            "scenario '{}': packing requires packings and \
             em_bandwidths_gbps",
            spec.name
        )));
    }

    // Job 0: sequential waves of whole-partition instances, local memory.
    let mut specs: Vec<SweepSpec> =
        Vec::with_capacity(1 + packings.len() * width);
    specs.push((
        d.build(total_nodes)?,
        base_cluster.clone(),
        EvalOptions {
            footprint_override: Some(d.footprint_per_node(total_nodes)),
            ..base_opts
        },
    ));
    for &n in packings {
        let w = d.build(n)?;
        let fp = d.footprint_per_node(n);
        let opts = EvalOptions {
            footprint_override: Some(fp),
            ..base_opts
        };
        for &bw in em_bandwidths_gbps {
            let mut cluster = base_cluster.with_n_nodes(n);
            let need = (fp - cluster.node.local.capacity).max(0.0);
            cluster.node = cluster.node.with_expanded(need, gb(bw));
            specs.push((w.clone(), cluster, opts));
        }
    }
    let inputs = coord.derive_batch_controlled(specs, control)?;
    let evals = coord.evaluate_inputs_controlled(&inputs, control)?;

    let base = evals[0].total() * instances;
    let mut fig = figure(spec, "packing");
    fig.columns = em_bandwidths_gbps
        .iter()
        .map(|b| format!("{b:.0}GB/s"))
        .collect();
    for (pi, &n) in packings.iter().enumerate() {
        let waves =
            (instances * n as f64 / total_nodes as f64).max(1.0).ceil();
        let vals: Vec<f64> = (0..width)
            .map(|j| base / (evals[1 + pi * width + j].total() * waves))
            .collect();
        fig.rows.push((format!("{n} nodes/instance"), vals));
    }
    Ok(fig)
}

// ---- pipeline (PP x microbatch x schedule case study) ---------------------

/// Resolve one pipeline lattice point into its 3D strategy; DP is
/// whatever is left of the cluster after MP x PP.
fn pipeline_point(
    spec: &ScenarioSpec,
    mp: usize,
    pp: usize,
) -> Result<Strategy> {
    let n = spec.cluster.n_nodes;
    if mp * pp == 0 || n % (mp * pp) != 0 {
        return Err(Error::Config(format!(
            "scenario '{}': MP{mp} x PP{pp} does not divide the {n}-node \
             cluster",
            spec.name
        )));
    }
    Strategy::new_3d(mp, n / (mp * pp), pp)
}

/// Row label of a pipeline lattice point.
fn pipeline_label(pp: usize, sched: PipeSchedule, multi_sched: bool) -> String {
    if multi_sched && pp > 1 {
        format!("PP{pp} {}", sched.name())
    } else {
        format!("PP{pp}")
    }
}

fn run_pipeline(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    mp: usize,
    pps: &[usize],
    microbatch_counts: &[usize],
    schedules: &[PipeSchedule],
    control: &RunControl,
) -> Result<FigureData> {
    let opts0 = eval_opts(spec);
    let multi_sched = schedules.len() > 1;
    let mut labels: Vec<String> = Vec::new();
    let mut specs: Vec<SweepSpec> = Vec::new();
    for &pp in pps {
        let s = pipeline_point(spec, mp, pp)?;
        let w = build_for(&spec.workload, &s)?;
        for &sched in schedules {
            // A PP1 row is the 2D slice: microbatching and schedule have
            // no effect, so emit it once.
            if pp == 1 && sched != schedules[0] {
                continue;
            }
            labels.push(pipeline_label(pp, sched, multi_sched));
            for &m in microbatch_counts {
                let o = EvalOptions {
                    microbatches: m,
                    pipe_schedule: sched,
                    ..opts0
                };
                specs.push((w.clone(), spec.cluster.clone(), o));
            }
        }
    }
    let inputs = coord.derive_batch_controlled(specs, control)?;
    let evals = coord.evaluate_inputs_controlled(&inputs, control)?;

    let width = microbatch_counts.len();
    let mut fig = figure(spec, "PP / schedule");
    fig.columns = microbatch_counts
        .iter()
        .map(|m| format!("m={m}"))
        .collect();
    for (i, label) in labels.into_iter().enumerate() {
        let vals: Vec<f64> = (0..width)
            .map(|j| evals[i * width + j].total())
            .collect();
        fig.rows.push((label, vals));
    }
    fig.notes.push(format!(
        "cells: iteration time (s); MP{mp} fixed, DP = nodes / (MP x PP)"
    ));
    Ok(fig)
}

fn run_tier_mapping(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    strategies: &StrategyAxis,
    mappings: &[TierMapping],
    control: &RunControl,
) -> Result<FigureData> {
    let opts0 = eval_opts(spec);
    let strategies = strategies.resolve(spec.cluster.n_nodes)?;
    let mut specs: Vec<SweepSpec> = Vec::new();
    for s in &strategies {
        let w = build_for(&spec.workload, s)?;
        for &mapping in mappings {
            let o = EvalOptions {
                tier_mapping: mapping,
                ..opts0
            };
            specs.push((w.clone(), spec.cluster.clone(), o));
        }
    }
    let inputs = coord.derive_batch_controlled(specs, control)?;
    let evals = coord.evaluate_inputs_controlled(&inputs, control)?;

    let width = mappings.len();
    let mut fig = figure(spec, "strategy");
    fig.columns = mappings.iter().map(|m| m.name().to_string()).collect();
    for (i, s) in strategies.iter().enumerate() {
        let vals: Vec<f64> = (0..width)
            .map(|j| evals[i * width + j].total())
            .collect();
        fig.rows.push((s.label(), vals));
    }
    fig.notes.push(
        "cells: iteration time (s); columns: which strategy axis maps to \
         the innermost fabric tiers"
            .into(),
    );
    Ok(fig)
}

/// The pipeline study's lattice as optimizer branches: one branch per
/// (PP, schedule, microbatch-count) point, so the branch-and-bound
/// search returns its argmin with the same pruning guarantees as an
/// optimize study.
fn pipeline_optimizer<'a>(
    spec: &ScenarioSpec,
    coord: &'a Coordinator,
    mp: usize,
    pps: &[usize],
    microbatch_counts: &[usize],
    schedules: &[PipeSchedule],
) -> Result<Optimizer<'a>> {
    let opts0 = eval_opts(spec);
    let mut branches: Vec<Branch> = Vec::new();
    for &pp in pps {
        let s = pipeline_point(spec, mp, pp)?;
        let w = build_for(&spec.workload, &s)?;
        for &sched in schedules {
            if pp == 1 && sched != schedules[0] {
                continue;
            }
            for &m in microbatch_counts {
                if pp == 1 && m != microbatch_counts[0] {
                    continue;
                }
                let label = if pp == 1 {
                    s.label()
                } else {
                    format!("{} {} m{m}", s.label(), sched.name())
                };
                branches.push(Branch {
                    label,
                    workload: w.clone(),
                    stage: opts0.zero_stage,
                    footprint_override: None,
                    microbatches: Some(m),
                    schedule: Some(sched),
                });
            }
        }
    }
    let axes =
        AxisSpec::new().collective_impls(&[opts0.collective_impl]);
    Optimizer::new(coord, spec.cluster.clone(), opts0, branches, axes)
        .map_err(|e| Error::Config(format!("scenario '{}': {e}", spec.name)))
}

// ---- optimize (branch-and-bound co-design search) -------------------------

/// Build the branch-and-bound optimizer a `kind = "optimize"` scenario
/// describes — or the PP x microbatch x schedule lattice of a
/// `kind = "pipeline"` scenario (one branch per lattice point, so
/// `comet optimize pipeline-transformer` searches the same space the
/// study tabulates) — without running it. Public so tests and
/// `bench_optimizer` can drive [`Optimizer::search`] and
/// [`Optimizer::exhaustive`] from the same spec and compare
/// evaluated-point counts.
pub fn optimizer_for<'a>(
    spec: &ScenarioSpec,
    coord: &'a Coordinator,
) -> Result<Optimizer<'a>> {
    if let Study::Pipeline {
        mp,
        pps,
        microbatch_counts,
        schedules,
    } = &spec.study
    {
        return pipeline_optimizer(
            spec,
            coord,
            *mp,
            pps,
            microbatch_counts,
            schedules,
        );
    }
    let Study::Optimize {
        strategies,
        em_bandwidths_gbps,
        em_capacities_gb,
        collectives,
        zero_stages,
        top_k,
        threads,
        objective,
        // Execution knobs are consumed by `run_optimize_exec`, not the
        // search-space construction.
        deadline_s: _,
        checkpoint: _,
        checkpoint_every_s: _,
    } = &spec.study
    else {
        return Err(Error::Config(format!(
            "scenario '{}': optimizer_for needs an optimize or pipeline \
             study, got {}",
            spec.name,
            spec.study.kind()
        )));
    };
    let opts0 = eval_opts(spec);
    let explicit_zero = !zero_stages.is_empty();
    let zaxis: Vec<ZeroStage> = if explicit_zero {
        zero_stages.clone()
    } else {
        vec![opts0.zero_stage]
    };

    let mut branches: Vec<Branch> = Vec::new();
    match &spec.workload {
        WorkloadSpec::Dlrm(d) => {
            // DLRM parallelism is rigid: one branch at the cluster size,
            // footprint from the embedding-shard model (not the generic
            // ZeRO formula).
            let default_axis = StrategyAxis::Pow2 {
                min_mp: 1,
                max_mp: None,
                max_pp: 1,
            };
            if *strategies != default_axis {
                return Err(Error::Config(format!(
                    "scenario '{}': a dlrm optimize study has no strategy \
                     axis; remove 'strategies'/'min_mp'/'max_mp'",
                    spec.name
                )));
            }
            if explicit_zero {
                return Err(Error::Config(format!(
                    "scenario '{}': zero_stages requires a transformer or \
                     gemm workload",
                    spec.name
                )));
            }
            let n = spec.cluster.n_nodes;
            branches.push(Branch {
                label: format!("{n} nodes"),
                workload: d.build(n)?,
                stage: opts0.zero_stage,
                footprint_override: Some(d.footprint_per_node(n)),
                microbatches: None,
                schedule: None,
            });
        }
        _ => {
            for s in strategies.resolve(spec.cluster.n_nodes)? {
                let w0 = build_for(&spec.workload, &s)?;
                for &stage in &zaxis {
                    let w = if explicit_zero {
                        apply_zero_comm(w0.clone(), stage)
                    } else {
                        w0.clone()
                    };
                    let label = if explicit_zero {
                        format!("{} {}", s.label(), stage.label())
                    } else {
                        s.label()
                    };
                    branches.push(Branch {
                        label,
                        workload: w,
                        stage,
                        footprint_override: None,
                        microbatches: None,
                        schedule: None,
                    });
                }
            }
        }
    }

    let mut axes = AxisSpec::new();
    if !em_bandwidths_gbps.is_empty() {
        let bws: Vec<f64> =
            em_bandwidths_gbps.iter().map(|&b| gb(b)).collect();
        axes = axes.em_bandwidths(&bws);
    }
    if !em_capacities_gb.is_empty() {
        let caps: Vec<f64> = em_capacities_gb.iter().map(|&c| gb(c)).collect();
        axes = axes.em_capacities(&caps);
    }
    if !collectives.is_empty() {
        axes = axes.collective_impls(collectives);
    } else {
        axes = axes.collective_impls(&[opts0.collective_impl]);
    }

    // A goodput search with no [resilience] table still needs a fault
    // model to rank against — fall back to the representative defaults.
    let faults = if *objective == Objective::Goodput
        && spec.resilience == FaultModel::none()
    {
        FaultModel::default_faults()
    } else {
        spec.resilience
    };
    let mut opt =
        Optimizer::new(coord, spec.cluster.clone(), opts0, branches, axes)
            .map_err(|e| {
                Error::Config(format!("scenario '{}': {e}", spec.name))
            })?
            .with_top_k(*top_k)
            .with_objective(*objective, faults)
            .map_err(|e| {
                Error::Config(format!("scenario '{}': {e}", spec.name))
            })?;
    if let Some(t) = threads {
        opt = opt.with_threads(*t);
    }
    Ok(opt)
}

/// Runtime execution inputs the CLI layers on top of a spec: an
/// externally-owned cancel token (wired to SIGINT by `comet optimize`)
/// and a checkpoint file to resume from. The spec-level knobs
/// (`deadline_s`, `checkpoint`, `checkpoint_every_s` on the study) are
/// read from the study itself.
#[derive(Debug, Clone, Default)]
pub struct ExecOverrides {
    /// Cooperative cancel signal observed at safe search boundaries.
    pub token: Option<CancelToken>,
    /// Path to a checkpoint written by a previous interrupted run.
    pub resume: Option<String>,
    /// `--deadline` flag; outranks the study's `deadline_s`.
    pub deadline_s: Option<f64>,
    /// `--checkpoint` flag; outranks the study's `checkpoint`.
    pub checkpoint: Option<String>,
    /// `--checkpoint-every` flag; outranks `checkpoint_every_s`.
    pub checkpoint_every_s: Option<f64>,
}

/// Assemble the [`SearchExec`] described by an optimize study's
/// execution knobs plus the CLI's runtime overrides (flags outrank the
/// spec; pipeline studies carry no knobs, so only flags apply there).
fn search_exec(spec: &ScenarioSpec, ex: &ExecOverrides) -> Result<SearchExec> {
    let (spec_d, spec_c, spec_e) = match &spec.study {
        Study::Optimize {
            deadline_s,
            checkpoint,
            checkpoint_every_s,
            ..
        } => (*deadline_s, checkpoint.clone(), *checkpoint_every_s),
        _ => (None, None, None),
    };
    let deadline_s = ex.deadline_s.or(spec_d);
    let ckpt = ex.checkpoint.clone().or(spec_c);
    let every = ex.checkpoint_every_s.or(spec_e);
    if every.is_some() && ckpt.is_none() {
        return Err(Error::Config(
            "--checkpoint-every requires a checkpoint path \
             (--checkpoint or the study's 'checkpoint')"
                .into(),
        ));
    }
    let mut control = RunControl::unbounded();
    if let Some(t) = &ex.token {
        control = control.with_token(t.clone());
    }
    if let Some(d) = deadline_s {
        control = control.with_deadline(Deadline::after_secs(d));
    }
    let mut exec = SearchExec::default().with_control(control);
    if let Some(p) = ckpt {
        exec = exec.with_checkpoint(p.into());
    }
    if let Some(e) = every {
        exec = exec.with_checkpoint_every(e);
    }
    if let Some(path) = &ex.resume {
        exec = exec.with_resume(Checkpoint::load(Path::new(path))?);
    }
    Ok(exec)
}

/// Run an optimize scenario, returning both the rendered figure (the
/// top-k table) and the full search [`Outcome`] (argmin, frontier,
/// evaluated/pruned counts).
pub fn run_optimize(
    spec: &ScenarioSpec,
    coord: &Coordinator,
) -> Result<(FigureData, Outcome)> {
    run_optimize_exec(spec, coord, &ExecOverrides::default())
}

/// [`run_optimize`] with runtime execution inputs. A search stopped by
/// a deadline or cancel returns a **partial** outcome (`!out.complete`)
/// rendered with explicit `PARTIAL` notes instead of an error, so an
/// interrupted run still reports its best-so-far table.
pub fn run_optimize_exec(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    ex: &ExecOverrides,
) -> Result<(FigureData, Outcome)> {
    let exec = search_exec(spec, ex)?;
    let out = optimizer_for(spec, coord)?.search_with(&exec)?;
    if out.complete && out.best().is_none() {
        return Err(Error::Config(format!(
            "scenario '{}': no feasible configuration in the design space \
             ({} points, all capacity-infeasible)",
            spec.name, out.total_points
        )));
    }
    let on_frontier: std::collections::HashSet<usize> =
        out.frontier.iter().map(|c| c.point.index).collect();

    // The top-k rows are a breakdown table like every other study — go
    // through the shared renderer (top[0] is the minimum, so
    // Normalize::Best yields Norm_to_best = total/argmin) and append the
    // one optimizer-specific column.
    let mut fig = figure(spec, "configuration");
    let evals: Vec<TrainingBreakdown> =
        out.top.iter().map(|c| c.breakdown).collect();
    render_breakdown(
        &mut fig,
        &evals,
        out.top.iter().map(|c| c.label.clone()).collect(),
        Some(out.top.iter().map(|c| c.footprint).collect()),
        Normalize::Best,
        "Norm_to_first",
    );
    fig.columns.push("Pareto".into());
    for (row, c) in fig.rows.iter_mut().zip(&out.top) {
        row.1.push(if on_frontier.contains(&c.point.index) {
            1.0
        } else {
            0.0
        });
    }
    if matches!(
        spec.study,
        Study::Optimize {
            objective: Objective::Goodput,
            ..
        }
    ) {
        fig.columns.push("Efficiency".into());
        fig.columns.push("Effective_s".into());
        for (row, c) in fig.rows.iter_mut().zip(&out.top) {
            row.1.push(c.efficiency);
            row.1.push(c.score);
        }
        fig.notes.push(
            "objective: goodput — ranked by Effective_s = Total_s / \
             efficiency under the [resilience] fault model"
                .into(),
        );
    }
    if let Some(stop) = out.stop {
        fig.notes.push(format!(
            "PARTIAL ({}): search stopped early with {} of {} lattice \
             points unexplored — rows are best-so-far; resume from the \
             checkpoint to finish",
            stop.label(),
            out.remaining,
            out.total_points
        ));
    }
    fig.notes.push(format!(
        "search: evaluated {}/{} lattice points ({} pruned by bound, {} \
         infeasible)",
        out.evaluated, out.total_points, out.pruned, out.infeasible
    ));
    fig.notes.push(format!(
        "pareto frontier (compute vs exposed comm): {} of {} evaluated \
         configurations",
        out.frontier.len(),
        out.evaluated
    ));
    apply_columns_override(spec, &mut fig)?;
    Ok((fig, out))
}

/// One row of a `--cross-check des` report: the total the search ranked
/// a candidate by vs a fresh DES re-simulation of the same resolved
/// inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct DesCrossCheck {
    /// The candidate's label (branch + explicit axes).
    pub label: String,
    /// The search's evaluated total, seconds.
    pub analytical_s: f64,
    /// The DES re-simulation's total, seconds.
    pub des_s: f64,
    /// Relative difference of the two totals.
    pub rel_diff: f64,
}

/// Re-simulate a finished optimize search's top-k through the
/// discrete-event engine — `comet optimize --cross-check des`. Each
/// candidate resolves back to the exact `ModelInputs` its evaluation
/// saw ([`Optimizer::inputs_for`]) and runs through
/// [`crate::sim::simulate_with`] on one reused scratch, so the
/// whole top-k re-check costs k back-to-back allocation-free DES runs.
/// Divergence beyond the DES validation band (~5%) flags a point whose
/// analytical ranking should not be trusted blindly.
pub fn cross_check_des(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    out: &Outcome,
) -> Result<Vec<DesCrossCheck>> {
    let opt = optimizer_for(spec, coord)?;
    let mut scratch = crate::sim::SimScratch::new();
    let mut rows = Vec::with_capacity(out.top.len());
    for c in &out.top {
        let inputs = opt.inputs_for(c)?;
        let des_s =
            crate::sim::simulate_with(&inputs, &mut scratch).breakdown.total();
        let analytical_s = c.total();
        rows.push(DesCrossCheck {
            label: c.label.clone(),
            analytical_s,
            des_s,
            rel_diff: crate::util::stats::rel_diff(analytical_s, des_s),
        });
    }
    Ok(rows)
}

// ---- resilience (goodput vs MTBF sweep) -----------------------------------

/// Goodput sensitivity study: rows are strategies, columns are per-node
/// MTBF values, cells are the fault-adjusted effective iteration time
/// `total / efficiency` under the scenario's `[resilience]` model with
/// the column's MTBF substituted in. The ideal step time is evaluated
/// once per strategy (it does not depend on MTBF); only the analytical
/// goodput factor varies across columns. Expanded memory is attached
/// exactly like the fig9 grid — capacity sized to each strategy's spill
/// over local HBM — so strategies that lean on memory expansion
/// checkpoint a larger footprint and pay for it as MTBF shrinks.
fn run_resilience(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    strategies: &StrategyAxis,
    mtbf_hours: &[f64],
    em_bandwidth_gbps: Option<f64>,
    deadline_s: Option<f64>,
    control: &RunControl,
) -> Result<FigureData> {
    // A `deadline_s` budget stops the sweep at the next batch boundary
    // with [`Error::Deadline`] — the study is one derive + one evaluate
    // call, so there is no meaningful partial table to salvage. It
    // composes with the caller's control (a serve request deadline or
    // cancellation token): whichever budget expires first stops the
    // sweep.
    let mut control = control.clone();
    if let Some(d) = deadline_s {
        control = control.with_deadline_sooner(Deadline::after_secs(d));
    }
    let strategies = strategies.resolve(spec.cluster.n_nodes)?;
    let opts0 = eval_opts(spec);
    let bw_inter = spec.cluster.inter_bandwidth();
    let bw_lm = spec.cluster.node.local.bandwidth;

    // One evaluation job per strategy; checkpoint footprint and
    // bandwidth recorded alongside for the per-column goodput factors.
    let mut specs: Vec<SweepSpec> = Vec::with_capacity(strategies.len());
    let mut footprints = Vec::with_capacity(strategies.len());
    let mut ckpt_bws = Vec::with_capacity(strategies.len());
    for s in &strategies {
        let w = build_for(&spec.workload, s)?;
        let fp = pipeline_footprint_per_node(
            &w,
            opts0.zero_stage,
            opts0.pipe_schedule,
            opts0.microbatches,
        );
        let mut cluster = spec.cluster.clone();
        let need = (fp - cluster.node.local.capacity).max(0.0);
        let mut bw_em = 0.0;
        if need > 0.0 {
            let bw = em_bandwidth_gbps.ok_or_else(|| {
                Error::Config(format!(
                    "scenario '{}': {} spills {:.0} GB over local memory \
                     but no em_bandwidth_gbps is set",
                    spec.name,
                    s.label(),
                    need / gb(1.0)
                ))
            })?;
            bw_em = gb(bw);
            cluster.node = cluster.node.with_expanded(need, bw_em);
        }
        footprints.push(fp);
        ckpt_bws.push(checkpoint_bandwidth(bw_inter, bw_lm, bw_em));
        specs.push((w, cluster, opts0));
    }
    let inputs = coord.derive_batch_controlled(specs, &control)?;
    let evals = coord.evaluate_inputs_controlled(&inputs, &control)?;

    let mut fig = figure(spec, "(MP, DP)");
    fig.columns = mtbf_hours.iter().map(|h| format!("MTBF_{h}h")).collect();
    for (i, s) in strategies.iter().enumerate() {
        let vals: Vec<f64> = mtbf_hours
            .iter()
            .map(|&h| {
                let fault = FaultModel {
                    mtbf_node_hours: h,
                    ..spec.resilience
                };
                goodput::analyze(
                    &fault,
                    spec.cluster.n_nodes,
                    footprints[i],
                    ckpt_bws[i],
                    &evals[i],
                )
                .effective_time(evals[i].total())
            })
            .collect();
        fig.rows.push((s.label(), vals));
    }

    // Per-column argmin: where the preferred design flips as failures
    // get more frequent.
    let argmin_of = |col: usize| {
        let mut best = 0;
        for (r, row) in fig.rows.iter().enumerate() {
            if row.1[col] < fig.rows[best].1[col] {
                best = r;
            }
        }
        fig.rows[best].0.clone()
    };
    let argmins: Vec<String> = (0..mtbf_hours.len())
        .map(|c| format!("{}h: {}", mtbf_hours[c], argmin_of(c)))
        .collect();
    fig.notes
        .push(format!("best per MTBF column: {}", argmins.join(", ")));
    Ok(fig)
}

// ---- cluster comparison (Fig. 15 shape) -----------------------------------

fn run_cluster_compare(
    spec: &ScenarioSpec,
    coord: &Coordinator,
    cluster_names: &[String],
    d: &crate::workload::dlrm::Dlrm,
    instances: f64,
    partition: usize,
    control: &RunControl,
) -> Result<FigureData> {
    let t = match &spec.workload {
        WorkloadSpec::Transformer(t) => t,
        _ => {
            return Err(Error::Config(format!(
                "scenario '{}': cluster-compare requires a transformer \
                 workload (the DLRM rides in [study])",
                spec.name
            )))
        }
    };
    let clusters: Vec<ClusterConfig> = cluster_names
        .iter()
        .map(|n| {
            crate::config::presets::by_name(n).ok_or_else(|| {
                Error::Config(format!(
                    "scenario '{}': unknown cluster preset '{n}'",
                    spec.name
                ))
            })
        })
        .collect::<Result<_>>()?;

    struct Plan {
        dlrm_idx: usize,
        waves: f64,
        tf: std::ops::Range<usize>,
    }
    let mut specs: Vec<SweepSpec> = Vec::new();
    let mut plans = Vec::with_capacity(clusters.len());
    for cluster in &clusters {
        let pool = cluster.n_nodes.min(partition);
        let n_i = dlrm_nodes_per_instance(cluster, d).min(pool);
        let waves = (instances * n_i as f64 / pool as f64).max(1.0).ceil();
        let sub = cluster.with_n_nodes(n_i);
        let w = d.build(n_i)?;
        let opts = EvalOptions {
            footprint_override: Some(d.footprint_per_node(n_i)),
            ..eval_opts(spec)
        };
        let dlrm_idx = specs.len();
        specs.push((w, sub, opts));

        let topts = eval_opts(spec);
        let tf_start = specs.len();
        let max_mp = 128.min(cluster.n_nodes);
        for s in Strategy::sweep_bounded(cluster.n_nodes, 1, max_mp)? {
            let w = t.build(&s)?;
            let fp = pipeline_footprint_per_node(
                &w,
                topts.zero_stage,
                topts.pipe_schedule,
                topts.microbatches,
            );
            if fp > cluster.node.total_capacity() {
                continue;
            }
            specs.push((w, cluster.clone(), topts));
        }
        plans.push(Plan {
            dlrm_idx,
            waves,
            tf: tf_start..specs.len(),
        });
    }

    let inputs = coord.derive_batch_controlled(specs, control)?;
    let evals = coord.evaluate_inputs_controlled(&inputs, control)?;

    let dlrm_times: Vec<f64> = plans
        .iter()
        .map(|p| evals[p.dlrm_idx].total() * p.waves)
        .collect();
    let tf_times: Vec<f64> = plans
        .iter()
        .map(|p| {
            if p.tf.is_empty() {
                f64::NAN
            } else {
                evals[p.tf.clone()]
                    .iter()
                    .map(|b| b.total())
                    .fold(f64::INFINITY, f64::min)
            }
        })
        .collect();

    let mut fig = figure(spec, "cluster");
    fig.columns = vec![format!("DLRM_x{instances}"), t.name.clone()];
    for (i, c) in clusters.iter().enumerate() {
        fig.rows.push((
            c.name.clone(),
            vec![
                dlrm_times[0] / dlrm_times[i],
                tf_times[0] / tf_times[i],
            ],
        ));
    }
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::ScenarioSpec;

    fn run_str(doc: &str) -> Result<FigureData> {
        let spec = ScenarioSpec::parse_str(doc)?;
        run(&spec, &Coordinator::native())
    }

    #[test]
    fn small_grid_breakdown_runs() {
        let f = run_str(
            "name = \"t\"\n\
             [workload]\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"grid\"\nmin_mp = 1\nmax_mp = 8\n\
             [options]\ninfinite_memory = true\n\
             [output]\nnormalize = \"best\"\nfootprint = true\n",
        )
        .unwrap();
        assert_eq!(f.rows.len(), 4); // MP8, MP4, MP2, MP1 on 64 nodes
        assert_eq!(f.columns.len(), 7 + 2);
        let best = f
            .rows
            .iter()
            .map(|(_, v)| v[7])
            .fold(f64::INFINITY, f64::min);
        assert!((best - 1.0).abs() < 1e-12);
    }

    #[test]
    fn run_controlled_stops_at_batch_boundaries() {
        let spec = crate::scenario::registry::get("quickstart").unwrap();
        let coord = Coordinator::native();
        let cancelled = RunControl::unbounded().cancel_after_polls(0);
        let err = run_controlled(&spec, &coord, &cancelled).unwrap_err();
        assert!(matches!(err, Error::Cancelled(_)), "{err}");
        // An unbounded control is exactly `run`.
        let a =
            run_controlled(&spec, &coord, &RunControl::unbounded()).unwrap();
        let b = run(&spec, &coord).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn gemm_grid_runs() {
        let f = run_str(
            "name = \"g\"\n\
             [workload]\nkind = \"gemm\"\nm = 65536\nk = 8192\nn = 8192\n\
             [study]\nkind = \"grid\"\n\
             strategies = [\"MP1_DP1\", \"MP1_DP8\", \"MP1_DP64\"]\n",
        )
        .unwrap();
        assert_eq!(f.rows.len(), 3);
        // More DP = less per-node work = faster.
        assert!(f.rows[0].1[6] > f.rows[2].1[6]);
    }

    #[test]
    fn speedup_without_baseline_errors() {
        let e = run_str(
            "name = \"t\"\n[study]\nkind = \"grid\"\n\
             strategies = [\"MP8_DP128\"]\n\
             em_bandwidths_gbps = [500]\n\
             [output]\ncontent = \"speedup\"\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("baseline"), "{e}");
    }

    #[test]
    fn cluster_size_requires_dlrm() {
        let e = run_str(
            "name = \"t\"\n[study]\nkind = \"cluster-size\"\n\
             sizes = [64, 32]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("dlrm"), "{e}");
    }

    #[test]
    fn columns_override_must_match_width() {
        let e = run_str(
            "name = \"t\"\n\
             [workload]\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"grid\"\nmax_mp = 2\n\
             [output]\ncolumns = [\"just-one\"]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("columns"), "{e}");
    }

    #[test]
    fn compute_scaling_needs_unit_scale() {
        let e = run_str(
            "name = \"t\"\n[study]\nkind = \"compute-scaling\"\n\
             strategy = \"MP8_DP128\"\nscales = [0.5, 2.0]\n\
             em_bandwidths_gbps = [2039]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("1.0"), "{e}");
    }

    #[test]
    fn optimize_study_runs_and_reports_search_stats() {
        let f = run_str(
            "name = \"opt\"\n\
             [workload]\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"optimize\"\nmin_mp = 1\nmax_mp = 8\n\
             top_k = 3\n\
             [options]\ninfinite_memory = true\n",
        )
        .unwrap();
        assert_eq!(f.rows.len(), 3);
        assert_eq!(f.columns.len(), 7 + 3);
        // Row 0 is the argmin: normalized total exactly 1.
        let norm = f.columns.iter().position(|c| c == "Norm_to_best").unwrap();
        assert_eq!(f.rows[0].1[norm], 1.0);
        assert!(f
            .rows
            .iter()
            .all(|(_, v)| v[norm] >= 1.0));
        assert!(f
            .notes
            .iter()
            .any(|n| n.contains("evaluated") && n.contains("pruned")));
    }

    #[test]
    fn optimize_dlrm_rejects_strategy_and_zero_axes() {
        let e = run_str(
            "name = \"opt\"\n[workload]\nkind = \"dlrm\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"optimize\"\n\
             strategies = [\"MP8_DP8\"]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("strategy"), "{e}");
        let e = run_str(
            "name = \"opt\"\n[workload]\nkind = \"dlrm\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"optimize\"\nzero_stages = [2, 3]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("zero_stages"), "{e}");
    }

    #[test]
    fn pipeline_study_runs_and_dedups_pp1_rows() {
        let f = run_str(
            "name = \"pipe\"\n[workload]\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"pipeline\"\nmp = 2\npps = [1, 2, 4]\n\
             microbatches = [4, 8]\nschedules = [\"gpipe\", \"1f1b\"]\n\
             [options]\ninfinite_memory = true\n",
        )
        .unwrap();
        // PP1 appears once (schedule-independent); PP2/PP4 per schedule.
        assert_eq!(f.rows.len(), 1 + 2 * 2);
        assert_eq!(f.columns, vec!["m=4".to_string(), "m=8".to_string()]);
        assert_eq!(f.rows[0].0, "PP1");
        assert!(f.rows.iter().any(|(l, _)| l == "PP4 1f1b"));
        for (label, vals) in &f.rows {
            for v in vals {
                assert!(v.is_finite() && *v > 0.0, "{label}: {v}");
            }
        }
        // More microbatches shrink the bubble: for PP > 1 rows the m=8
        // column must not be meaningfully slower than m=4 (per-hop
        // latency grows with m, so allow a whisker).
        for (label, vals) in f.rows.iter().skip(1) {
            assert!(vals[1] <= vals[0] * 1.02, "{label}: {vals:?}");
        }
    }

    #[test]
    fn pipeline_study_rejects_bad_shapes() {
        // MP x PP must divide the cluster.
        let e = run_str(
            "name = \"pipe\"\n[workload]\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"pipeline\"\nmp = 2\npps = [3]\n\
             microbatches = [4]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("divide"), "{e}");
        // DLRM has no pipeline axis.
        let e = run_str(
            "name = \"pipe\"\n[workload]\nkind = \"dlrm\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"pipeline\"\npps = [2]\nmicrobatches = [4]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("transformer"), "{e}");
    }

    #[test]
    fn pipeline_study_searchable_via_optimizer() {
        let spec = ScenarioSpec::parse_str(
            "name = \"pipe\"\n[workload]\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"pipeline\"\nmp = 2\npps = [1, 2, 4]\n\
             microbatches = [4, 8]\nschedules = [\"gpipe\", \"1f1b\"]\n\
             [options]\ninfinite_memory = true\n",
        )
        .unwrap();
        let coord = Coordinator::native();
        let opt = optimizer_for(&spec, &coord).unwrap();
        let s = opt.search().unwrap();
        let e = opt.exhaustive().unwrap();
        // PP1 collapses to one branch; PP2/PP4 span 2 schedules x 2 m.
        assert_eq!(e.total_points, 1 + 2 * 4);
        assert_eq!(s.best().unwrap().label, e.best().unwrap().label);
        assert_eq!(
            s.best().unwrap().total().to_bits(),
            e.best().unwrap().total().to_bits()
        );
        assert_eq!(s.evaluated + s.pruned, e.evaluated);
    }

    #[test]
    fn em_capacity_without_bandwidth_is_an_error() {
        let e = run_str(
            "name = \"t\"\n[study]\nkind = \"grid\"\n\
             strategies = [\"MP8_DP128\"]\nem_capacities_gb = [100]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("bandwidth"), "{e}");
    }

    #[test]
    fn resilience_study_runs_and_orders_by_mtbf() {
        let f = run_str(
            "name = \"res\"\n\
             [workload]\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [resilience]\nrestart_s = 120\n\
             [study]\nkind = \"resilience\"\nmin_mp = 1\nmax_mp = 8\n\
             mtbf_hours = [100000, 500, 50]\n",
        )
        .unwrap();
        assert_eq!(f.rows.len(), 4); // MP8..MP1 on 64 nodes
        assert_eq!(
            f.columns,
            vec!["MTBF_100000h", "MTBF_500h", "MTBF_50h"]
        );
        for (label, vals) in &f.rows {
            // Effective time is finite, positive, and monotonically
            // non-improving as MTBF shrinks (left-to-right).
            for v in vals {
                assert!(v.is_finite() && *v > 0.0, "{label}: {v}");
            }
            assert!(vals[0] <= vals[1] && vals[1] <= vals[2], "{label}");
        }
        assert!(f.notes.iter().any(|n| n.contains("best per MTBF")), "{f:?}");
    }

    #[test]
    fn resilience_spill_without_em_bandwidth_is_an_error() {
        // Transformer-1T at MP2 spills far past 80 GB of local HBM; the
        // study must demand an EM bandwidth rather than silently placing
        // the footprint nowhere.
        let e = run_str(
            "name = \"res\"\n\
             [workload]\npreset = \"transformer-1t\"\n\
             [cluster]\npreset = \"baseline\"\n\
             [study]\nkind = \"resilience\"\nmin_mp = 2\nmax_mp = 2\n\
             mtbf_hours = [500]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("em_bandwidth_gbps"), "{e}");
    }

    #[test]
    fn goodput_objective_reports_efficiency_columns() {
        let f = run_str(
            "name = \"opt\"\n\
             [workload]\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [resilience]\nmtbf_node_hours = 200\nrestart_s = 120\n\
             [study]\nkind = \"optimize\"\nmin_mp = 1\nmax_mp = 8\n\
             top_k = 3\nobjective = \"goodput\"\n\
             [options]\ninfinite_memory = true\n",
        )
        .unwrap();
        let eff = f.columns.iter().position(|c| c == "Efficiency").unwrap();
        let es = f.columns.iter().position(|c| c == "Effective_s").unwrap();
        let total = f.columns.iter().position(|c| c == "Total_s").unwrap();
        for (label, vals) in &f.rows {
            assert!(vals[eff] > 0.0 && vals[eff] <= 1.0, "{label}");
            // Effective_s = Total_s / efficiency >= Total_s.
            assert!(vals[es] >= vals[total], "{label}");
        }
        // Rows are ranked by the goodput score, not raw time.
        for w in f.rows.windows(2) {
            assert!(w[0].1[es] <= w[1].1[es]);
        }
        assert!(f.notes.iter().any(|n| n.contains("goodput")), "{f:?}");
    }
}
