//! The declarative scenario model: everything a cluster-design study needs
//! — workload, cluster, sweep axes, evaluation options, and presentation —
//! as plain data with a strict JSON mapping.
//!
//! A [`ScenarioSpec`] is parsed from TOML/JSON (see [`super::parse`]),
//! resolves presets eagerly (so equality and serialization always operate
//! on fully-resolved values), and is lowered onto the batched evaluation
//! hot path by [`super::run()`]. Unknown keys are errors: a typo in a
//! scenario file fails loudly instead of silently reverting to a default.

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::{presets, serde_io, ClusterConfig};
use crate::coordinator::Coordinator;
use crate::error::{Error, Result};
use crate::network::CollectiveImpl;
use crate::optimizer::Objective;
use crate::parallel::{PipeSchedule, Strategy, TierMapping, ZeroStage};
use crate::resilience::FaultModel;
use crate::util::json::Value;
use crate::workload::dlrm::Dlrm;
use crate::workload::gemm::DenseGemm;
use crate::workload::transformer::Transformer;

/// A complete declarative scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Identifier; becomes the output figure's `id`.
    pub name: String,
    /// Human title; becomes the output figure's `title`.
    pub title: String,
    /// The workload under study.
    pub workload: WorkloadSpec,
    /// The (fully resolved) base cluster.
    pub cluster: ClusterConfig,
    /// The study shape: which axes are swept and how.
    pub study: Study,
    /// Evaluation options applied to every point.
    pub options: OptionsSpec,
    /// Fault model for goodput objectives and `resilience` studies
    /// (the `[resilience]` table; defaults to no faults).
    pub resilience: FaultModel,
    /// Output presentation.
    pub output: OutputSpec,
}

/// The workload under study, with presets resolved to concrete knobs.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// A Megatron-style transformer (MP x DP sweepable).
    Transformer(Transformer),
    /// A DLRM (rigid hybrid parallelism; node-count studies).
    Dlrm(Dlrm),
    /// A single dense GEMM microbenchmark (DP sweepable).
    Gemm(DenseGemm),
}

/// A strategy axis: either the power-of-two (MP, DP[, PP]) sweep bounded
/// by MP degree (and optionally grown by the pipeline axis), or an
/// explicit list.
#[derive(Debug, Clone, PartialEq)]
pub enum StrategyAxis {
    /// `Strategy::sweep_bounded(n_nodes, min_mp, max_mp)` when
    /// `max_pp == 1`, else the 3D `Strategy::sweep_3d` lattice;
    /// `max_mp = None` means unbounded (the full sweep).
    Pow2 {
        /// Smallest MP degree included.
        min_mp: usize,
        /// Largest MP degree included (`None` = the cluster size).
        max_mp: Option<usize>,
        /// Largest pipeline-parallel degree included (1 = the paper's 2D
        /// lattice; the default).
        max_pp: usize,
    },
    /// Explicit strategy list (2D or 3D labels), in row order.
    List(Vec<Strategy>),
}

impl StrategyAxis {
    /// Resolve against a cluster of `n_nodes`; errors on a
    /// non-power-of-two cluster size.
    pub fn resolve(&self, n_nodes: usize) -> Result<Vec<Strategy>> {
        match self {
            StrategyAxis::Pow2 {
                min_mp,
                max_mp,
                max_pp,
            } => Strategy::sweep_3d(
                n_nodes,
                *min_mp,
                max_mp.unwrap_or(n_nodes),
                *max_pp,
            ),
            StrategyAxis::List(v) => Ok(v.clone()),
        }
    }
}

/// The study shape. `Grid` is the general-purpose cross-product engine;
/// the remaining variants parameterize the paper's bespoke case-study
/// shapes (compute/network scaling, DLRM cluster sizing and packing, the
/// Table III cluster comparison).
#[derive(Debug, Clone, PartialEq)]
pub enum Study {
    /// Pure ZeRO footprint model over a strategy sweep (paper Fig. 6) —
    /// no cost-model evaluation.
    Footprint {
        /// Rows of the footprint table.
        strategies: StrategyAxis,
    },
    /// Cross-product sweep: strategies x expanded-memory bandwidth x
    /// expanded-memory capacity x collective implementation x ZeRO stage,
    /// lowered onto [`crate::coordinator::GridSweep`].
    Grid {
        /// Strategy axis (always present; single-element for fixed-point
        /// studies).
        strategies: StrategyAxis,
        /// Expanded-memory bandwidths, GB/s (empty = local memory only).
        em_bandwidths_gbps: Vec<f64>,
        /// Expanded-memory capacities, GB (empty = sized to the spill).
        em_capacities_gb: Vec<f64>,
        /// Collective implementations (empty = the options default).
        collectives: Vec<CollectiveImpl>,
        /// ZeRO stages (empty = the options default). When explicit, each
        /// stage's DP communication-volume multiplier is applied.
        zero_stages: Vec<ZeroStage>,
        /// Normalization baseline evaluated on the base cluster (local
        /// memory), e.g. Fig. 9's MP64_DP16.
        baseline: Option<Strategy>,
    },
    /// Per-node compute-capability scaling at a fixed strategy, across
    /// expanded-memory bandwidths (paper Fig. 10).
    ComputeScaling {
        /// The fixed parallelization strategy.
        strategy: Strategy,
        /// Peak-compute multipliers (rows); must include 1.0 (baseline).
        scales: Vec<f64>,
        /// Expanded-memory bandwidths, GB/s (columns).
        em_bandwidths_gbps: Vec<f64>,
    },
    /// Intra-/inter-pod bandwidth scaling grid (paper Fig. 11).
    NetworkScaling {
        /// Strategies studied (row groups).
        strategies: Vec<Strategy>,
        /// Intra-pod bandwidth multipliers.
        intra_factors: Vec<f64>,
        /// Inter-pod bandwidth multipliers.
        inter_factors: Vec<f64>,
    },
    /// Rebalancing a fixed aggregate per-node bandwidth between intra- and
    /// inter-pod links (paper Fig. 12).
    NetworkRebalance {
        /// Strategies studied (columns).
        strategies: Vec<Strategy>,
        /// intra:inter bandwidth ratios (rows).
        ratios: Vec<f64>,
    },
    /// DLRM iteration time vs cluster size (paper Fig. 13a). Requires a
    /// DLRM workload.
    ClusterSize {
        /// Node counts (rows); the first is the normalization baseline.
        sizes: Vec<usize>,
        /// Expanded-memory bandwidth attached where the shard spills,
        /// GB/s (`None` = never attach expanded memory).
        em_bandwidth_gbps: Option<f64>,
    },
    /// Multi-instance DLRM turnaround vs expanded-memory bandwidth for
    /// different nodes-per-instance packings (paper Fig. 13b). Requires a
    /// DLRM workload.
    Packing {
        /// Instances trained (the turnaround job).
        instances: f64,
        /// Nodes per instance (rows).
        packings: Vec<usize>,
        /// Expanded-memory bandwidths, GB/s (columns).
        em_bandwidths_gbps: Vec<f64>,
    },
    /// Branch-and-bound co-design search over the strategy x
    /// expanded-memory x collective x ZeRO lattice
    /// ([`crate::optimizer`]): returns the argmin, the top-k, and the
    /// compute-vs-communication Pareto frontier while pruning with
    /// admissible analytical bounds instead of evaluating the whole
    /// grid.
    Optimize {
        /// Strategy axis (transformer/gemm workloads; a DLRM workload
        /// has rigid parallelism and must leave this at the default).
        strategies: StrategyAxis,
        /// Expanded-memory bandwidths, GB/s (empty = local memory only).
        em_bandwidths_gbps: Vec<f64>,
        /// Expanded-memory capacities, GB (empty = sized to the spill).
        em_capacities_gb: Vec<f64>,
        /// Collective implementations (empty = the options default).
        collectives: Vec<CollectiveImpl>,
        /// ZeRO stages (empty = the options default). When explicit, each
        /// stage's DP communication-volume multiplier is applied.
        zero_stages: Vec<ZeroStage>,
        /// How many best configurations to report (default 5).
        top_k: usize,
        /// Evaluation lanes for the branch-and-bound search (`None` =
        /// the coordinator's worker-pool width; `1` = the sequential
        /// driver). The outcome is bit-identical at every width — this
        /// only trades wall-clock.
        threads: Option<usize>,
        /// Ranking objective: raw iteration time (default) or
        /// fault-adjusted goodput under the scenario's `[resilience]`
        /// model ([`crate::optimizer::Objective`]).
        objective: Objective,
        /// Wall-clock budget for the search, seconds (`None` =
        /// unbounded). On expiry the search stops at a safe boundary
        /// and reports its partial best-so-far result.
        deadline_s: Option<f64>,
        /// Checkpoint file the search flushes its resumable state to on
        /// stop (and on the interval below). `comet optimize
        /// --resume <path>` continues from it bit-identically.
        checkpoint: Option<String>,
        /// Also checkpoint every this-many seconds at safe boundaries
        /// (`0` = every boundary; `None` = only on stop). Requires
        /// `checkpoint`.
        checkpoint_every_s: Option<f64>,
    },
    /// Goodput sensitivity study: fault-adjusted effective iteration
    /// time per strategy across a node-MTBF sweep, using the scenario's
    /// `[resilience]` table for everything but the swept MTBF. Shows
    /// where the preferred design flips as failures get more frequent.
    Resilience {
        /// Strategy axis (rows).
        strategies: StrategyAxis,
        /// Per-node MTBF values swept, hours (columns).
        mtbf_hours: Vec<f64>,
        /// Expanded-memory bandwidth attached where the footprint
        /// spills, GB/s (`None` = never attach expanded memory).
        em_bandwidth_gbps: Option<f64>,
        /// Wall-clock budget for the sweep, seconds (`None` =
        /// unbounded). On expiry the run stops with a deadline error at
        /// the next strategy/MTBF cell boundary.
        deadline_s: Option<f64>,
    },
    /// Pipeline-parallelism case study: at a fixed MP degree, sweep the
    /// PP degree x microbatch count x schedule on one cluster (DP is
    /// derived per point as `n_nodes / (mp * pp)`). Rows are
    /// (PP, schedule), columns are microbatch counts, cells are iteration
    /// time.
    Pipeline {
        /// Fixed model-parallel degree.
        mp: usize,
        /// Pipeline degrees swept (row groups); `1` rows are the 2D
        /// slice and ignore microbatch count and schedule.
        pps: Vec<usize>,
        /// Microbatch counts swept (columns).
        microbatch_counts: Vec<usize>,
        /// Schedules swept (rows within a PP group; both by default).
        schedules: Vec<PipeSchedule>,
    },
    /// Tier-mapping case study on a multi-tier cluster: which strategy
    /// axis lives on which fabric tier. Rows are strategies, columns are
    /// [`TierMapping`]s (MP innermost vs DP innermost), cells are
    /// iteration time — the tiered analogue of the paper's network
    /// placement discussion.
    TierMapping {
        /// Strategy axis (rows).
        strategies: StrategyAxis,
        /// Mappings compared (columns; both by default).
        mappings: Vec<TierMapping>,
    },
    /// Cross-cluster comparison on DLRM turnaround + best-feasible
    /// transformer strategy (paper Fig. 15 / Table III).
    ClusterCompare {
        /// Preset cluster names, in row order; the first is the
        /// normalization baseline.
        clusters: Vec<String>,
        /// The DLRM co-workload.
        dlrm: Dlrm,
        /// DLRM instances for the turnaround column.
        instances: f64,
        /// GPU partition size DLRM instances wave over (paper: 64).
        partition: usize,
    },
}

impl Study {
    /// The spec-file `kind` string of this study.
    pub fn kind(&self) -> &'static str {
        match self {
            Study::Footprint { .. } => "footprint",
            Study::Grid { .. } => "grid",
            Study::ComputeScaling { .. } => "compute-scaling",
            Study::NetworkScaling { .. } => "network-scaling",
            Study::NetworkRebalance { .. } => "network-rebalance",
            Study::ClusterSize { .. } => "cluster-size",
            Study::Packing { .. } => "packing",
            Study::Optimize { .. } => "optimize",
            Study::Resilience { .. } => "resilience",
            Study::Pipeline { .. } => "pipeline",
            Study::TierMapping { .. } => "tier-mapping",
            Study::ClusterCompare { .. } => "cluster-compare",
        }
    }
}

/// Which cost-model backend a scenario requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendSpec {
    /// Closed-form f64 evaluation (default).
    #[default]
    Native,
    /// Discrete-event simulation.
    Des,
    /// AOT artifact via PJRT; errors if artifacts are absent.
    Artifact,
    /// Artifact if available, else native.
    Auto,
}

impl BackendSpec {
    /// Build a coordinator for this backend.
    pub fn coordinator(&self) -> Result<Coordinator> {
        match self {
            BackendSpec::Native => Ok(Coordinator::native()),
            BackendSpec::Des => Ok(Coordinator::des()),
            BackendSpec::Artifact => Coordinator::artifact(),
            BackendSpec::Auto => Ok(Coordinator::auto()),
        }
    }

    fn as_str(&self) -> &'static str {
        match self {
            BackendSpec::Native => "native",
            BackendSpec::Des => "des",
            BackendSpec::Artifact => "artifact",
            BackendSpec::Auto => "auto",
        }
    }
}

/// Evaluation options (the spec-level mirror of
/// [`crate::model::inputs::EvalOptions`], plus the backend choice).
#[derive(Debug, Clone, PartialEq)]
pub struct OptionsSpec {
    /// Backend evaluating the scenario.
    pub backend: BackendSpec,
    /// Default ZeRO stage (footprints and DP partitioning).
    pub zero_stage: ZeroStage,
    /// Assume infinite capacity at full local bandwidth (Fig. 8a mode).
    pub infinite_memory: bool,
    /// Default collective implementation.
    pub collective: CollectiveImpl,
    /// Overlap WG communication with WG compute.
    pub overlap_wg: bool,
    /// Force the expanded-memory traffic fraction (sensitivity studies).
    pub em_frac: Option<f64>,
    /// Default microbatch count for pipeline-parallel points (ignored on
    /// the `pp = 1` slice).
    pub microbatches: usize,
    /// Default pipeline schedule (`gpipe` | `1f1b`; ignored at `pp = 1`).
    pub schedule: PipeSchedule,
    /// Which strategy axis maps to the innermost fabric tiers on a
    /// multi-tier topology (`mp-inner` | `dp-inner`; ignored on legacy
    /// two-level clusters, which always resolve MP innermost).
    pub tier_mapping: TierMapping,
}

impl Default for OptionsSpec {
    fn default() -> Self {
        OptionsSpec {
            backend: BackendSpec::Native,
            zero_stage: ZeroStage::OsG,
            infinite_memory: false,
            collective: CollectiveImpl::LogicalRing,
            overlap_wg: true,
            em_frac: None,
            microbatches: 8,
            schedule: PipeSchedule::OneFOneB,
            tier_mapping: TierMapping::MpInner,
        }
    }
}

/// Output rendering format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OutputFormat {
    /// Boxed ASCII table (default).
    #[default]
    Table,
    /// CSV.
    Csv,
    /// JSON.
    Json,
}

impl OutputFormat {
    fn as_str(&self) -> &'static str {
        match self {
            OutputFormat::Table => "table",
            OutputFormat::Csv => "csv",
            OutputFormat::Json => "json",
        }
    }
}

/// What the result grid contains (applies to `footprint`/`grid` studies;
/// the other study kinds have a fixed presentation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Content {
    /// Study-dependent default: `Speedup` when the grid has a baseline,
    /// else `Breakdown`.
    #[default]
    Auto,
    /// Six-phase time breakdown + total per point.
    Breakdown,
    /// Compute vs exposed-communication fractions (Fig. 8b).
    Share,
    /// Speedup over the baseline, pivoted on the expanded-memory
    /// bandwidth axis (Fig. 9).
    Speedup,
    /// Side-by-side totals for exactly two collective implementations.
    CollectiveContrast,
    /// Footprint + total + exposed WG communication per ZeRO stage.
    ZeroTable,
}

impl Content {
    fn as_str(&self) -> &'static str {
        match self {
            Content::Auto => "auto",
            Content::Breakdown => "breakdown",
            Content::Share => "share",
            Content::Speedup => "speedup",
            Content::CollectiveContrast => "collective-contrast",
            Content::ZeroTable => "zero-table",
        }
    }
}

/// Normalization column added to `Breakdown` output.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Normalize {
    /// No normalization column.
    #[default]
    None,
    /// Normalize totals to the best (minimum) total.
    Best,
    /// Normalize totals to the first row.
    First,
}

impl Normalize {
    fn as_str(&self) -> &'static str {
        match self {
            Normalize::None => "none",
            Normalize::Best => "best",
            Normalize::First => "first",
        }
    }
}

/// Output presentation.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct OutputSpec {
    /// Rendering format for `comet scenario run`.
    pub format: OutputFormat,
    /// Grid content selector.
    pub content: Content,
    /// Normalization column for breakdown content.
    pub normalize: Normalize,
    /// Append a per-point `Footprint_GB` column to breakdown content.
    pub footprint: bool,
    /// Row-dimension label (`None` = the study's default).
    pub row_label: Option<String>,
    /// Column-header override (length must match the produced grid).
    pub columns: Option<Vec<String>>,
    /// Free-form notes copied into the figure.
    pub notes: Vec<String>,
}

// ---- JSON (de)serialization ----------------------------------------------

fn map_of<'a>(v: &'a Value, ctx: &str) -> Result<&'a BTreeMap<String, Value>> {
    match v {
        Value::Obj(m) => Ok(m),
        _ => Err(Error::Config(format!("scenario: '{ctx}' must be a table"))),
    }
}

fn check_keys(
    m: &BTreeMap<String, Value>,
    allowed: &[&str],
    ctx: &str,
) -> Result<()> {
    for k in m.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(Error::Config(format!(
                "scenario: unknown key '{k}' in {ctx} (allowed: {})",
                allowed.join(", ")
            )));
        }
    }
    Ok(())
}

fn opt_str(m: &BTreeMap<String, Value>, key: &str, ctx: &str) -> Result<Option<String>> {
    match m.get(key) {
        None => Ok(None),
        Some(Value::Str(s)) => Ok(Some(s.clone())),
        Some(_) => Err(Error::Config(format!(
            "scenario: '{key}' in {ctx} must be a string"
        ))),
    }
}

fn opt_f64(m: &BTreeMap<String, Value>, key: &str, ctx: &str) -> Result<Option<f64>> {
    match m.get(key) {
        None => Ok(None),
        Some(Value::Num(n)) => Ok(Some(*n)),
        Some(_) => Err(Error::Config(format!(
            "scenario: '{key}' in {ctx} must be a number"
        ))),
    }
}

fn opt_usize(m: &BTreeMap<String, Value>, key: &str, ctx: &str) -> Result<Option<usize>> {
    match opt_f64(m, key, ctx)? {
        None => Ok(None),
        Some(n) if n >= 0.0 && n.fract() == 0.0 => Ok(Some(n as usize)),
        Some(n) => Err(Error::Config(format!(
            "scenario: '{key}' in {ctx} must be a non-negative integer, got {n}"
        ))),
    }
}

fn opt_bool(m: &BTreeMap<String, Value>, key: &str, ctx: &str) -> Result<Option<bool>> {
    match m.get(key) {
        None => Ok(None),
        Some(Value::Bool(b)) => Ok(Some(*b)),
        Some(_) => Err(Error::Config(format!(
            "scenario: '{key}' in {ctx} must be a boolean"
        ))),
    }
}

fn f64_list(m: &BTreeMap<String, Value>, key: &str, ctx: &str) -> Result<Vec<f64>> {
    match m.get(key) {
        None => Ok(Vec::new()),
        Some(Value::Arr(a)) => a
            .iter()
            .map(|v| {
                v.as_f64().ok_or_else(|| {
                    Error::Config(format!(
                        "scenario: '{key}' in {ctx} must contain numbers"
                    ))
                })
            })
            .collect(),
        Some(_) => Err(Error::Config(format!(
            "scenario: '{key}' in {ctx} must be an array"
        ))),
    }
}

fn usize_list(m: &BTreeMap<String, Value>, key: &str, ctx: &str) -> Result<Vec<usize>> {
    f64_list(m, key, ctx)?
        .into_iter()
        .map(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Ok(n as usize)
            } else {
                Err(Error::Config(format!(
                    "scenario: '{key}' in {ctx} must contain integers, got {n}"
                )))
            }
        })
        .collect()
}

fn str_list(m: &BTreeMap<String, Value>, key: &str, ctx: &str) -> Result<Vec<String>> {
    match m.get(key) {
        None => Ok(Vec::new()),
        Some(Value::Arr(a)) => a
            .iter()
            .map(|v| {
                v.as_str().map(|s| s.to_string()).ok_or_else(|| {
                    Error::Config(format!(
                        "scenario: '{key}' in {ctx} must contain strings"
                    ))
                })
            })
            .collect(),
        Some(_) => Err(Error::Config(format!(
            "scenario: '{key}' in {ctx} must be an array"
        ))),
    }
}

fn strategy_list(m: &BTreeMap<String, Value>, key: &str, ctx: &str) -> Result<Vec<Strategy>> {
    str_list(m, key, ctx)?
        .iter()
        .map(|s| Strategy::parse(s))
        .collect()
}

/// Parse a spec-file ZeRO stage number (0|1|2|3; anything else —
/// including non-integers — is rejected). Shared with `comet optimize`'s
/// `--zero-stages` flag so the two surfaces cannot drift.
pub fn zero_stage_of(n: f64) -> Result<ZeroStage> {
    match n {
        x if x == 0.0 => Ok(ZeroStage::Baseline),
        x if x == 1.0 => Ok(ZeroStage::Os),
        x if x == 2.0 => Ok(ZeroStage::OsG),
        x if x == 3.0 => Ok(ZeroStage::OsGP),
        other => Err(Error::Config(format!(
            "scenario: unknown ZeRO stage {other} (0|1|2|3)"
        ))),
    }
}

fn zero_stage_code(s: ZeroStage) -> f64 {
    match s {
        ZeroStage::Baseline => 0.0,
        ZeroStage::Os => 1.0,
        ZeroStage::OsG => 2.0,
        ZeroStage::OsGP => 3.0,
    }
}

/// Parse a spec-file collective name (`ring` | `hierarchical`). Shared
/// with `comet optimize`'s `--collectives` flag; inverse of
/// [`collective_name`].
pub fn collective_of(s: &str) -> Result<CollectiveImpl> {
    match s {
        "ring" => Ok(CollectiveImpl::LogicalRing),
        "hierarchical" => Ok(CollectiveImpl::Hierarchical),
        other => Err(Error::Config(format!(
            "scenario: unknown collective '{other}' (ring|hierarchical)"
        ))),
    }
}

/// Short spec-file name of a collective implementation (delegates to
/// [`CollectiveImpl::name`] so every surface shares one vocabulary).
pub fn collective_name(c: CollectiveImpl) -> &'static str {
    c.name()
}

impl WorkloadSpec {
    fn from_json(v: &Value) -> Result<WorkloadSpec> {
        let m = map_of(v, "workload")?;
        let kind = opt_str(m, "kind", "workload")?
            .unwrap_or_else(|| "transformer".into());
        match kind.as_str() {
            "transformer" => {
                check_keys(
                    m,
                    &[
                        "kind", "preset", "name", "stacks", "d_model",
                        "heads", "seq", "vocab", "ff_mult", "batch",
                    ],
                    "workload",
                )?;
                let mut t = match opt_str(m, "preset", "workload")?
                    .as_deref()
                    .unwrap_or("transformer-1t")
                {
                    "transformer-1t" => Transformer::t1(),
                    "transformer-100m" => Transformer::t100m(),
                    other => {
                        return Err(Error::Config(format!(
                            "scenario: unknown transformer preset '{other}'"
                        )))
                    }
                };
                if let Some(s) = opt_str(m, "name", "workload")? {
                    t.name = s;
                }
                if let Some(n) = opt_usize(m, "stacks", "workload")? {
                    t.stacks = n;
                }
                if let Some(x) = opt_f64(m, "d_model", "workload")? {
                    t.d_model = x;
                }
                if let Some(x) = opt_f64(m, "heads", "workload")? {
                    t.heads = x;
                }
                if let Some(x) = opt_f64(m, "seq", "workload")? {
                    t.seq = x;
                }
                if let Some(x) = opt_f64(m, "vocab", "workload")? {
                    t.vocab = x;
                }
                if let Some(x) = opt_f64(m, "ff_mult", "workload")? {
                    t.ff_mult = x;
                }
                if let Some(x) = opt_f64(m, "batch", "workload")? {
                    t.batch = x;
                }
                Ok(WorkloadSpec::Transformer(t))
            }
            "dlrm" => Ok(WorkloadSpec::Dlrm(dlrm_from_map(m)?)),
            "gemm" => {
                check_keys(m, &["kind", "name", "m", "k", "n"], "workload")?;
                let req = |key: &str| {
                    opt_f64(m, key, "workload")?.ok_or_else(|| {
                        Error::Config(format!(
                            "scenario: gemm workload requires '{key}'"
                        ))
                    })
                };
                let mut g = DenseGemm::new(req("m")?, req("k")?, req("n")?);
                if let Some(s) = opt_str(m, "name", "workload")? {
                    g.name = s;
                }
                Ok(WorkloadSpec::Gemm(g))
            }
            other => Err(Error::Config(format!(
                "scenario: unknown workload kind '{other}' \
                 (transformer|dlrm|gemm)"
            ))),
        }
    }

    fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        match self {
            WorkloadSpec::Transformer(t) => {
                m.insert("kind".into(), Value::Str("transformer".into()));
                m.insert("name".into(), Value::Str(t.name.clone()));
                m.insert("stacks".into(), Value::Num(t.stacks as f64));
                m.insert("d_model".into(), Value::Num(t.d_model));
                m.insert("heads".into(), Value::Num(t.heads));
                m.insert("seq".into(), Value::Num(t.seq));
                m.insert("vocab".into(), Value::Num(t.vocab));
                m.insert("ff_mult".into(), Value::Num(t.ff_mult));
                m.insert("batch".into(), Value::Num(t.batch));
            }
            WorkloadSpec::Dlrm(d) => {
                m.insert("kind".into(), Value::Str("dlrm".into()));
                dlrm_to_map(d, &mut m);
            }
            WorkloadSpec::Gemm(g) => {
                m.insert("kind".into(), Value::Str("gemm".into()));
                m.insert("name".into(), Value::Str(g.name.clone()));
                m.insert("m".into(), Value::Num(g.m));
                m.insert("k".into(), Value::Num(g.k));
                m.insert("n".into(), Value::Num(g.n));
            }
        }
        Value::Obj(m)
    }

    /// Workload display name.
    pub fn name(&self) -> &str {
        match self {
            WorkloadSpec::Transformer(t) => &t.name,
            WorkloadSpec::Dlrm(d) => &d.name,
            WorkloadSpec::Gemm(g) => &g.name,
        }
    }
}

fn dlrm_from_map(m: &BTreeMap<String, Value>) -> Result<Dlrm> {
    check_keys(
        m,
        &[
            "kind", "preset", "name", "emb_params", "emb_dim", "tables",
            "pooling", "bottom_mlp", "top_mlp", "global_batch",
        ],
        "dlrm spec",
    )?;
    let mut d = match opt_str(m, "preset", "workload")?
        .as_deref()
        .unwrap_or("dlrm-1.2t")
    {
        "dlrm-1.2t" => Dlrm::dlrm_1_2t(),
        "dlrm-small" => Dlrm::small(),
        other => {
            return Err(Error::Config(format!(
                "scenario: unknown dlrm preset '{other}'"
            )))
        }
    };
    if let Some(s) = opt_str(m, "name", "workload")? {
        d.name = s;
    }
    if let Some(x) = opt_f64(m, "emb_params", "workload")? {
        d.emb_params = x;
    }
    if let Some(x) = opt_f64(m, "emb_dim", "workload")? {
        d.emb_dim = x;
    }
    if let Some(x) = opt_f64(m, "tables", "workload")? {
        d.tables = x;
    }
    if let Some(x) = opt_f64(m, "pooling", "workload")? {
        d.pooling = x;
    }
    if let Some(x) = opt_f64(m, "global_batch", "workload")? {
        d.global_batch = x;
    }
    if m.contains_key("bottom_mlp") {
        d.bottom_mlp = f64_list(m, "bottom_mlp", "workload")?;
    }
    if m.contains_key("top_mlp") {
        d.top_mlp = f64_list(m, "top_mlp", "workload")?;
    }
    Ok(d)
}

fn dlrm_to_map(d: &Dlrm, m: &mut BTreeMap<String, Value>) {
    m.insert("name".into(), Value::Str(d.name.clone()));
    m.insert("emb_params".into(), Value::Num(d.emb_params));
    m.insert("emb_dim".into(), Value::Num(d.emb_dim));
    m.insert("tables".into(), Value::Num(d.tables));
    m.insert("pooling".into(), Value::Num(d.pooling));
    m.insert(
        "bottom_mlp".into(),
        Value::Arr(d.bottom_mlp.iter().map(|&x| Value::Num(x)).collect()),
    );
    m.insert(
        "top_mlp".into(),
        Value::Arr(d.top_mlp.iter().map(|&x| Value::Num(x)).collect()),
    );
    m.insert("global_batch".into(), Value::Num(d.global_batch));
}

fn cluster_from_json(v: &Value) -> Result<ClusterConfig> {
    let m = map_of(v, "cluster")?;
    if m.contains_key("preset") {
        let name = opt_str(m, "preset", "cluster")?.unwrap();
        let mut c = presets::by_name(&name).ok_or_else(|| {
            Error::Config(format!(
                "scenario: unknown cluster preset '{name}'; presets: {:?}",
                presets::preset_names()
            ))
        })?;
        serde_io::apply_cluster_overrides(&mut c, v)?;
        Ok(c)
    } else {
        // Inline clusters use the serde_io shape; reject stray keys so an
        // override-style key on an inline cluster cannot be dropped
        // silently.
        check_keys(
            m,
            &["name", "n_nodes", "link_latency", "node", "topology", "groups"],
            "cluster",
        )?;
        ClusterConfig::from_json(v)
    }
}

fn fault_model_from_json(v: &Value) -> Result<FaultModel> {
    let m = map_of(v, "resilience")?;
    check_keys(
        m,
        &[
            "mtbf_node_hours",
            "restart_s",
            "straggler_frac",
            "straggler_slowdown",
            "link_degrade_frac",
            "link_degrade_factor",
            "seed",
        ],
        "resilience",
    )?;
    let mut f = FaultModel::none();
    if let Some(x) = opt_f64(m, "mtbf_node_hours", "resilience")? {
        f.mtbf_node_hours = x;
    }
    if let Some(x) = opt_f64(m, "restart_s", "resilience")? {
        f.restart_s = x;
    }
    if let Some(x) = opt_f64(m, "straggler_frac", "resilience")? {
        f.straggler_frac = x;
    }
    if let Some(x) = opt_f64(m, "straggler_slowdown", "resilience")? {
        f.straggler_slowdown = x;
    }
    if let Some(x) = opt_f64(m, "link_degrade_frac", "resilience")? {
        f.link_degrade_frac = x;
    }
    if let Some(x) = opt_f64(m, "link_degrade_factor", "resilience")? {
        f.link_degrade_factor = x;
    }
    if let Some(n) = opt_usize(m, "seed", "resilience")? {
        f.seed = n as u64;
    }
    f.validate()?;
    Ok(f)
}

fn fault_model_to_json(f: &FaultModel) -> Value {
    let mut m = BTreeMap::new();
    // The disabled MTBF is infinity, which TOML/JSON numbers cannot
    // carry — omit it (the parse default) rather than serialize it.
    if f.mtbf_node_hours.is_finite() {
        m.insert("mtbf_node_hours".into(), Value::Num(f.mtbf_node_hours));
    }
    m.insert("restart_s".into(), Value::Num(f.restart_s));
    m.insert("straggler_frac".into(), Value::Num(f.straggler_frac));
    m.insert(
        "straggler_slowdown".into(),
        Value::Num(f.straggler_slowdown),
    );
    m.insert("link_degrade_frac".into(), Value::Num(f.link_degrade_frac));
    m.insert(
        "link_degrade_factor".into(),
        Value::Num(f.link_degrade_factor),
    );
    m.insert("seed".into(), Value::Num(f.seed as f64));
    Value::Obj(m)
}

impl Study {
    fn strategies_axis(m: &BTreeMap<String, Value>) -> Result<StrategyAxis> {
        match m.get("strategies") {
            None | Some(Value::Str(_)) => {
                if let Some(Value::Str(s)) = m.get("strategies") {
                    if s != "pow2" {
                        return Err(Error::Config(format!(
                            "scenario: strategies must be \"pow2\" or a \
                             list of MP<i>_DP<j> labels, got '{s}'"
                        )));
                    }
                }
                Ok(StrategyAxis::Pow2 {
                    min_mp: opt_usize(m, "min_mp", "study")?.unwrap_or(1),
                    max_mp: opt_usize(m, "max_mp", "study")?,
                    max_pp: match opt_usize(m, "max_pp", "study")? {
                        Some(0) => {
                            return Err(Error::Config(
                                "scenario: max_pp must be >= 1".into(),
                            ))
                        }
                        Some(p) => p,
                        None => 1,
                    },
                })
            }
            Some(Value::Arr(_)) => Ok(StrategyAxis::List(strategy_list(
                m,
                "strategies",
                "study",
            )?)),
            Some(_) => Err(Error::Config(
                "scenario: 'strategies' must be \"pow2\" or a list".into(),
            )),
        }
    }

    fn from_json(v: &Value) -> Result<Study> {
        let m = map_of(v, "study")?;
        let kind = opt_str(m, "kind", "study")?.ok_or_else(|| {
            Error::Config("scenario: study requires a 'kind'".into())
        })?;
        match kind.as_str() {
            "footprint" => {
                check_keys(
                    m,
                    &["kind", "strategies", "min_mp", "max_mp", "max_pp"],
                    "study",
                )?;
                Ok(Study::Footprint {
                    strategies: Self::strategies_axis(m)?,
                })
            }
            "grid" => {
                check_keys(
                    m,
                    &[
                        "kind",
                        "strategies",
                        "min_mp",
                        "max_mp",
                        "max_pp",
                        "em_bandwidths_gbps",
                        "em_capacities_gb",
                        "collectives",
                        "zero_stages",
                        "baseline",
                    ],
                    "study",
                )?;
                let collectives = str_list(m, "collectives", "study")?
                    .iter()
                    .map(|s| collective_of(s))
                    .collect::<Result<Vec<_>>>()?;
                let zero_stages = f64_list(m, "zero_stages", "study")?
                    .into_iter()
                    .map(zero_stage_of)
                    .collect::<Result<Vec<_>>>()?;
                let baseline = match opt_str(m, "baseline", "study")? {
                    Some(s) => Some(Strategy::parse(&s)?),
                    None => None,
                };
                Ok(Study::Grid {
                    strategies: Self::strategies_axis(m)?,
                    em_bandwidths_gbps: f64_list(
                        m,
                        "em_bandwidths_gbps",
                        "study",
                    )?,
                    em_capacities_gb: f64_list(m, "em_capacities_gb", "study")?,
                    collectives,
                    zero_stages,
                    baseline,
                })
            }
            "compute-scaling" => {
                check_keys(
                    m,
                    &["kind", "strategy", "scales", "em_bandwidths_gbps"],
                    "study",
                )?;
                let s = opt_str(m, "strategy", "study")?.ok_or_else(|| {
                    Error::Config(
                        "scenario: compute-scaling requires 'strategy'".into(),
                    )
                })?;
                Ok(Study::ComputeScaling {
                    strategy: Strategy::parse(&s)?,
                    scales: f64_list(m, "scales", "study")?,
                    em_bandwidths_gbps: f64_list(
                        m,
                        "em_bandwidths_gbps",
                        "study",
                    )?,
                })
            }
            "network-scaling" => {
                check_keys(
                    m,
                    &["kind", "strategies", "intra_factors", "inter_factors"],
                    "study",
                )?;
                Ok(Study::NetworkScaling {
                    strategies: strategy_list(m, "strategies", "study")?,
                    intra_factors: f64_list(m, "intra_factors", "study")?,
                    inter_factors: f64_list(m, "inter_factors", "study")?,
                })
            }
            "network-rebalance" => {
                check_keys(m, &["kind", "strategies", "ratios"], "study")?;
                Ok(Study::NetworkRebalance {
                    strategies: strategy_list(m, "strategies", "study")?,
                    ratios: f64_list(m, "ratios", "study")?,
                })
            }
            "cluster-size" => {
                check_keys(
                    m,
                    &["kind", "sizes", "em_bandwidth_gbps"],
                    "study",
                )?;
                Ok(Study::ClusterSize {
                    sizes: usize_list(m, "sizes", "study")?,
                    em_bandwidth_gbps: opt_f64(m, "em_bandwidth_gbps", "study")?,
                })
            }
            "packing" => {
                check_keys(
                    m,
                    &["kind", "instances", "packings", "em_bandwidths_gbps"],
                    "study",
                )?;
                Ok(Study::Packing {
                    instances: opt_f64(m, "instances", "study")?.unwrap_or(8.0),
                    packings: usize_list(m, "packings", "study")?,
                    em_bandwidths_gbps: f64_list(
                        m,
                        "em_bandwidths_gbps",
                        "study",
                    )?,
                })
            }
            "optimize" => {
                check_keys(
                    m,
                    &[
                        "kind",
                        "strategies",
                        "min_mp",
                        "max_mp",
                        "max_pp",
                        "em_bandwidths_gbps",
                        "em_capacities_gb",
                        "collectives",
                        "zero_stages",
                        "top_k",
                        "threads",
                        "objective",
                        "deadline_s",
                        "checkpoint",
                        "checkpoint_every_s",
                    ],
                    "study",
                )?;
                let collectives = str_list(m, "collectives", "study")?
                    .iter()
                    .map(|s| collective_of(s))
                    .collect::<Result<Vec<_>>>()?;
                let zero_stages = f64_list(m, "zero_stages", "study")?
                    .into_iter()
                    .map(zero_stage_of)
                    .collect::<Result<Vec<_>>>()?;
                let top_k = opt_usize(m, "top_k", "study")?.unwrap_or(5);
                if top_k == 0 {
                    return Err(Error::Config(
                        "scenario: optimize top_k must be >= 1".into(),
                    ));
                }
                let threads = opt_usize(m, "threads", "study")?;
                if threads == Some(0) {
                    return Err(Error::Config(
                        "scenario: optimize threads must be >= 1".into(),
                    ));
                }
                let objective = match opt_str(m, "objective", "study")? {
                    Some(s) => Objective::parse(&s)?,
                    None => Objective::Time,
                };
                let deadline_s = opt_f64(m, "deadline_s", "study")?;
                if let Some(d) = deadline_s {
                    if !(d >= 0.0) {
                        return Err(Error::Config(format!(
                            "scenario: optimize deadline_s must be >= 0, \
                             got {d}"
                        )));
                    }
                }
                let checkpoint = opt_str(m, "checkpoint", "study")?;
                let checkpoint_every_s =
                    opt_f64(m, "checkpoint_every_s", "study")?;
                if let Some(e) = checkpoint_every_s {
                    if !(e >= 0.0) {
                        return Err(Error::Config(format!(
                            "scenario: optimize checkpoint_every_s must be \
                             >= 0, got {e}"
                        )));
                    }
                    if checkpoint.is_none() {
                        return Err(Error::Config(
                            "scenario: optimize checkpoint_every_s requires \
                             'checkpoint'"
                                .into(),
                        ));
                    }
                }
                Ok(Study::Optimize {
                    strategies: Self::strategies_axis(m)?,
                    em_bandwidths_gbps: f64_list(
                        m,
                        "em_bandwidths_gbps",
                        "study",
                    )?,
                    em_capacities_gb: f64_list(m, "em_capacities_gb", "study")?,
                    collectives,
                    zero_stages,
                    top_k,
                    threads,
                    objective,
                    deadline_s,
                    checkpoint,
                    checkpoint_every_s,
                })
            }
            "resilience" => {
                check_keys(
                    m,
                    &[
                        "kind",
                        "strategies",
                        "min_mp",
                        "max_mp",
                        "max_pp",
                        "mtbf_hours",
                        "em_bandwidth_gbps",
                        "deadline_s",
                    ],
                    "study",
                )?;
                let mtbf_hours = f64_list(m, "mtbf_hours", "study")?;
                if mtbf_hours.is_empty() {
                    return Err(Error::Config(
                        "scenario: resilience study requires a non-empty \
                         'mtbf_hours' sweep"
                            .into(),
                    ));
                }
                for &h in &mtbf_hours {
                    if !(h > 0.0) {
                        return Err(Error::Config(format!(
                            "scenario: mtbf_hours entries must be positive, \
                             got {h}"
                        )));
                    }
                }
                let deadline_s = opt_f64(m, "deadline_s", "study")?;
                if let Some(d) = deadline_s {
                    if !(d >= 0.0) {
                        return Err(Error::Config(format!(
                            "scenario: resilience deadline_s must be >= 0, \
                             got {d}"
                        )));
                    }
                }
                Ok(Study::Resilience {
                    strategies: Self::strategies_axis(m)?,
                    mtbf_hours,
                    em_bandwidth_gbps: opt_f64(m, "em_bandwidth_gbps", "study")?,
                    deadline_s,
                })
            }
            "pipeline" => {
                check_keys(
                    m,
                    &["kind", "mp", "pps", "microbatches", "schedules"],
                    "study",
                )?;
                let pps = usize_list(m, "pps", "study")?;
                let microbatch_counts = usize_list(m, "microbatches", "study")?;
                if pps.is_empty() || microbatch_counts.is_empty() {
                    return Err(Error::Config(
                        "scenario: pipeline study requires non-empty 'pps' \
                         and 'microbatches'"
                            .into(),
                    ));
                }
                if pps.contains(&0) || microbatch_counts.contains(&0) {
                    return Err(Error::Config(
                        "scenario: pipeline degrees and microbatch counts \
                         must be >= 1"
                            .into(),
                    ));
                }
                let schedules = str_list(m, "schedules", "study")?
                    .iter()
                    .map(|s| PipeSchedule::parse(s))
                    .collect::<Result<Vec<_>>>()?;
                Ok(Study::Pipeline {
                    mp: match opt_usize(m, "mp", "study")? {
                        Some(0) => {
                            return Err(Error::Config(
                                "scenario: pipeline mp must be >= 1".into(),
                            ))
                        }
                        Some(p) => p,
                        None => 1,
                    },
                    pps,
                    microbatch_counts,
                    schedules: if schedules.is_empty() {
                        PipeSchedule::ALL.to_vec()
                    } else {
                        schedules
                    },
                })
            }
            "tier-mapping" => {
                check_keys(
                    m,
                    &[
                        "kind",
                        "strategies",
                        "min_mp",
                        "max_mp",
                        "max_pp",
                        "mappings",
                    ],
                    "study",
                )?;
                let mappings = str_list(m, "mappings", "study")?
                    .iter()
                    .map(|s| TierMapping::parse(s))
                    .collect::<Result<Vec<_>>>()?;
                Ok(Study::TierMapping {
                    strategies: Self::strategies_axis(m)?,
                    mappings: if mappings.is_empty() {
                        TierMapping::ALL.to_vec()
                    } else {
                        mappings
                    },
                })
            }
            "cluster-compare" => {
                check_keys(
                    m,
                    &["kind", "clusters", "dlrm", "instances", "partition"],
                    "study",
                )?;
                let clusters = str_list(m, "clusters", "study")?;
                for c in &clusters {
                    if presets::by_name(c).is_none() {
                        return Err(Error::Config(format!(
                            "scenario: unknown cluster preset '{c}' in \
                             cluster-compare"
                        )));
                    }
                }
                let dlrm = match m.get("dlrm") {
                    None => Dlrm::dlrm_1_2t(),
                    Some(Value::Str(p)) => {
                        let mut mm = BTreeMap::new();
                        mm.insert("preset".into(), Value::Str(p.clone()));
                        dlrm_from_map(&mm)?
                    }
                    Some(Value::Obj(mm)) => dlrm_from_map(mm)?,
                    Some(_) => {
                        return Err(Error::Config(
                            "scenario: 'dlrm' must be a preset name or a \
                             table"
                                .into(),
                        ))
                    }
                };
                Ok(Study::ClusterCompare {
                    clusters,
                    dlrm,
                    instances: opt_f64(m, "instances", "study")?.unwrap_or(8.0),
                    partition: opt_usize(m, "partition", "study")?
                        .unwrap_or(64),
                })
            }
            other => Err(Error::Config(format!(
                "scenario: unknown study kind '{other}'"
            ))),
        }
    }

    fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("kind".into(), Value::Str(self.kind().into()));
        let axis_to_json = |m: &mut BTreeMap<String, Value>, a: &StrategyAxis| {
            match a {
                StrategyAxis::Pow2 {
                    min_mp,
                    max_mp,
                    max_pp,
                } => {
                    m.insert("strategies".into(), Value::Str("pow2".into()));
                    m.insert("min_mp".into(), Value::Num(*min_mp as f64));
                    if let Some(x) = max_mp {
                        m.insert("max_mp".into(), Value::Num(*x as f64));
                    }
                    if *max_pp > 1 {
                        m.insert("max_pp".into(), Value::Num(*max_pp as f64));
                    }
                }
                StrategyAxis::List(v) => {
                    m.insert(
                        "strategies".into(),
                        Value::Arr(
                            v.iter()
                                .map(|s| Value::Str(s.label()))
                                .collect(),
                        ),
                    );
                }
            }
        };
        let strategies_json = |v: &[Strategy]| {
            Value::Arr(v.iter().map(|s| Value::Str(s.label())).collect())
        };
        let nums =
            |v: &[f64]| Value::Arr(v.iter().map(|&x| Value::Num(x)).collect());
        match self {
            Study::Footprint { strategies } => axis_to_json(&mut m, strategies),
            Study::Grid {
                strategies,
                em_bandwidths_gbps,
                em_capacities_gb,
                collectives,
                zero_stages,
                baseline,
            } => {
                axis_to_json(&mut m, strategies);
                if !em_bandwidths_gbps.is_empty() {
                    m.insert(
                        "em_bandwidths_gbps".into(),
                        nums(em_bandwidths_gbps),
                    );
                }
                if !em_capacities_gb.is_empty() {
                    m.insert("em_capacities_gb".into(), nums(em_capacities_gb));
                }
                if !collectives.is_empty() {
                    m.insert(
                        "collectives".into(),
                        Value::Arr(
                            collectives
                                .iter()
                                .map(|&c| {
                                    Value::Str(collective_name(c).into())
                                })
                                .collect(),
                        ),
                    );
                }
                if !zero_stages.is_empty() {
                    m.insert(
                        "zero_stages".into(),
                        Value::Arr(
                            zero_stages
                                .iter()
                                .map(|&s| Value::Num(zero_stage_code(s)))
                                .collect(),
                        ),
                    );
                }
                if let Some(b) = baseline {
                    m.insert("baseline".into(), Value::Str(b.label()));
                }
            }
            Study::ComputeScaling {
                strategy,
                scales,
                em_bandwidths_gbps,
            } => {
                m.insert("strategy".into(), Value::Str(strategy.label()));
                m.insert("scales".into(), nums(scales));
                m.insert(
                    "em_bandwidths_gbps".into(),
                    nums(em_bandwidths_gbps),
                );
            }
            Study::NetworkScaling {
                strategies,
                intra_factors,
                inter_factors,
            } => {
                m.insert("strategies".into(), strategies_json(strategies));
                m.insert("intra_factors".into(), nums(intra_factors));
                m.insert("inter_factors".into(), nums(inter_factors));
            }
            Study::NetworkRebalance { strategies, ratios } => {
                m.insert("strategies".into(), strategies_json(strategies));
                m.insert("ratios".into(), nums(ratios));
            }
            Study::ClusterSize {
                sizes,
                em_bandwidth_gbps,
            } => {
                m.insert(
                    "sizes".into(),
                    Value::Arr(
                        sizes.iter().map(|&n| Value::Num(n as f64)).collect(),
                    ),
                );
                if let Some(x) = em_bandwidth_gbps {
                    m.insert("em_bandwidth_gbps".into(), Value::Num(*x));
                }
            }
            Study::Packing {
                instances,
                packings,
                em_bandwidths_gbps,
            } => {
                m.insert("instances".into(), Value::Num(*instances));
                m.insert(
                    "packings".into(),
                    Value::Arr(
                        packings
                            .iter()
                            .map(|&n| Value::Num(n as f64))
                            .collect(),
                    ),
                );
                m.insert(
                    "em_bandwidths_gbps".into(),
                    nums(em_bandwidths_gbps),
                );
            }
            Study::Optimize {
                strategies,
                em_bandwidths_gbps,
                em_capacities_gb,
                collectives,
                zero_stages,
                top_k,
                threads,
                objective,
                deadline_s,
                checkpoint,
                checkpoint_every_s,
            } => {
                axis_to_json(&mut m, strategies);
                if !em_bandwidths_gbps.is_empty() {
                    m.insert(
                        "em_bandwidths_gbps".into(),
                        nums(em_bandwidths_gbps),
                    );
                }
                if !em_capacities_gb.is_empty() {
                    m.insert("em_capacities_gb".into(), nums(em_capacities_gb));
                }
                if !collectives.is_empty() {
                    m.insert(
                        "collectives".into(),
                        Value::Arr(
                            collectives
                                .iter()
                                .map(|&c| {
                                    Value::Str(collective_name(c).into())
                                })
                                .collect(),
                        ),
                    );
                }
                if !zero_stages.is_empty() {
                    m.insert(
                        "zero_stages".into(),
                        Value::Arr(
                            zero_stages
                                .iter()
                                .map(|&s| Value::Num(zero_stage_code(s)))
                                .collect(),
                        ),
                    );
                }
                m.insert("top_k".into(), Value::Num(*top_k as f64));
                if let Some(t) = threads {
                    m.insert("threads".into(), Value::Num(*t as f64));
                }
                // Emitted only when non-default so pre-objective exports
                // stay byte-identical.
                if *objective != Objective::Time {
                    m.insert(
                        "objective".into(),
                        Value::Str(objective.name().into()),
                    );
                }
                // Execution knobs are emitted only when set so exports
                // predating them stay byte-identical.
                if let Some(d) = deadline_s {
                    m.insert("deadline_s".into(), Value::Num(*d));
                }
                if let Some(p) = checkpoint {
                    m.insert("checkpoint".into(), Value::Str(p.clone()));
                }
                if let Some(e) = checkpoint_every_s {
                    m.insert("checkpoint_every_s".into(), Value::Num(*e));
                }
            }
            Study::Resilience {
                strategies,
                mtbf_hours,
                em_bandwidth_gbps,
                deadline_s,
            } => {
                axis_to_json(&mut m, strategies);
                m.insert("mtbf_hours".into(), nums(mtbf_hours));
                if let Some(x) = em_bandwidth_gbps {
                    m.insert("em_bandwidth_gbps".into(), Value::Num(*x));
                }
                if let Some(d) = deadline_s {
                    m.insert("deadline_s".into(), Value::Num(*d));
                }
            }
            Study::Pipeline {
                mp,
                pps,
                microbatch_counts,
                schedules,
            } => {
                m.insert("mp".into(), Value::Num(*mp as f64));
                m.insert(
                    "pps".into(),
                    Value::Arr(
                        pps.iter().map(|&p| Value::Num(p as f64)).collect(),
                    ),
                );
                m.insert(
                    "microbatches".into(),
                    Value::Arr(
                        microbatch_counts
                            .iter()
                            .map(|&n| Value::Num(n as f64))
                            .collect(),
                    ),
                );
                m.insert(
                    "schedules".into(),
                    Value::Arr(
                        schedules
                            .iter()
                            .map(|s| Value::Str(s.name().into()))
                            .collect(),
                    ),
                );
            }
            Study::TierMapping {
                strategies,
                mappings,
            } => {
                axis_to_json(&mut m, strategies);
                m.insert(
                    "mappings".into(),
                    Value::Arr(
                        mappings
                            .iter()
                            .map(|t| Value::Str(t.name().into()))
                            .collect(),
                    ),
                );
            }
            Study::ClusterCompare {
                clusters,
                dlrm,
                instances,
                partition,
            } => {
                m.insert(
                    "clusters".into(),
                    Value::Arr(
                        clusters
                            .iter()
                            .map(|c| Value::Str(c.clone()))
                            .collect(),
                    ),
                );
                let mut dm = BTreeMap::new();
                dlrm_to_map(dlrm, &mut dm);
                m.insert("dlrm".into(), Value::Obj(dm));
                m.insert("instances".into(), Value::Num(*instances));
                m.insert("partition".into(), Value::Num(*partition as f64));
            }
        }
        Value::Obj(m)
    }
}

impl OptionsSpec {
    fn from_json(v: &Value) -> Result<OptionsSpec> {
        let m = map_of(v, "options")?;
        check_keys(
            m,
            &[
                "backend",
                "zero_stage",
                "infinite_memory",
                "collective",
                "overlap_wg",
                "em_frac",
                "microbatches",
                "schedule",
                "tier_mapping",
            ],
            "options",
        )?;
        let mut o = OptionsSpec::default();
        if let Some(s) = opt_str(m, "backend", "options")? {
            o.backend = match s.as_str() {
                "native" => BackendSpec::Native,
                "des" => BackendSpec::Des,
                "artifact" => BackendSpec::Artifact,
                "auto" => BackendSpec::Auto,
                other => {
                    return Err(Error::Config(format!(
                        "scenario: unknown backend '{other}' \
                         (native|des|artifact|auto)"
                    )))
                }
            };
        }
        if let Some(n) = opt_f64(m, "zero_stage", "options")? {
            o.zero_stage = zero_stage_of(n)?;
        }
        if let Some(b) = opt_bool(m, "infinite_memory", "options")? {
            o.infinite_memory = b;
        }
        if let Some(s) = opt_str(m, "collective", "options")? {
            o.collective = collective_of(&s)?;
        }
        if let Some(b) = opt_bool(m, "overlap_wg", "options")? {
            o.overlap_wg = b;
        }
        o.em_frac = opt_f64(m, "em_frac", "options")?;
        if let Some(n) = opt_usize(m, "microbatches", "options")? {
            if n == 0 {
                return Err(Error::Config(
                    "scenario: microbatches must be >= 1".into(),
                ));
            }
            o.microbatches = n;
        }
        if let Some(s) = opt_str(m, "schedule", "options")? {
            o.schedule = PipeSchedule::parse(&s)?;
        }
        if let Some(s) = opt_str(m, "tier_mapping", "options")? {
            o.tier_mapping = TierMapping::parse(&s)?;
        }
        Ok(o)
    }

    fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("backend".into(), Value::Str(self.backend.as_str().into()));
        m.insert(
            "zero_stage".into(),
            Value::Num(zero_stage_code(self.zero_stage)),
        );
        m.insert(
            "infinite_memory".into(),
            Value::Bool(self.infinite_memory),
        );
        m.insert(
            "collective".into(),
            Value::Str(collective_name(self.collective).into()),
        );
        m.insert("overlap_wg".into(), Value::Bool(self.overlap_wg));
        if let Some(x) = self.em_frac {
            m.insert("em_frac".into(), Value::Num(x));
        }
        m.insert(
            "microbatches".into(),
            Value::Num(self.microbatches as f64),
        );
        m.insert(
            "schedule".into(),
            Value::Str(self.schedule.name().into()),
        );
        // Emitted only when non-default so legacy exports stay
        // byte-identical.
        if self.tier_mapping != TierMapping::MpInner {
            m.insert(
                "tier_mapping".into(),
                Value::Str(self.tier_mapping.name().into()),
            );
        }
        Value::Obj(m)
    }
}

impl OutputSpec {
    fn from_json(v: &Value) -> Result<OutputSpec> {
        let m = map_of(v, "output")?;
        check_keys(
            m,
            &[
                "format",
                "content",
                "normalize",
                "footprint",
                "row_label",
                "columns",
                "notes",
            ],
            "output",
        )?;
        let mut o = OutputSpec::default();
        if let Some(s) = opt_str(m, "format", "output")? {
            o.format = match s.as_str() {
                "table" => OutputFormat::Table,
                "csv" => OutputFormat::Csv,
                "json" => OutputFormat::Json,
                other => {
                    return Err(Error::Config(format!(
                        "scenario: unknown output format '{other}' \
                         (table|csv|json)"
                    )))
                }
            };
        }
        if let Some(s) = opt_str(m, "content", "output")? {
            o.content = match s.as_str() {
                "auto" => Content::Auto,
                "breakdown" => Content::Breakdown,
                "share" => Content::Share,
                "speedup" => Content::Speedup,
                "collective-contrast" => Content::CollectiveContrast,
                "zero-table" => Content::ZeroTable,
                other => {
                    return Err(Error::Config(format!(
                        "scenario: unknown content '{other}'"
                    )))
                }
            };
        }
        if let Some(s) = opt_str(m, "normalize", "output")? {
            o.normalize = match s.as_str() {
                "none" => Normalize::None,
                "best" => Normalize::Best,
                "first" => Normalize::First,
                other => {
                    return Err(Error::Config(format!(
                        "scenario: unknown normalize '{other}' \
                         (none|best|first)"
                    )))
                }
            };
        }
        if let Some(b) = opt_bool(m, "footprint", "output")? {
            o.footprint = b;
        }
        o.row_label = opt_str(m, "row_label", "output")?;
        if m.contains_key("columns") {
            o.columns = Some(str_list(m, "columns", "output")?);
        }
        o.notes = str_list(m, "notes", "output")?;
        Ok(o)
    }

    fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("format".into(), Value::Str(self.format.as_str().into()));
        m.insert("content".into(), Value::Str(self.content.as_str().into()));
        m.insert(
            "normalize".into(),
            Value::Str(self.normalize.as_str().into()),
        );
        m.insert("footprint".into(), Value::Bool(self.footprint));
        if let Some(r) = &self.row_label {
            m.insert("row_label".into(), Value::Str(r.clone()));
        }
        if let Some(cols) = &self.columns {
            m.insert(
                "columns".into(),
                Value::Arr(
                    cols.iter().map(|c| Value::Str(c.clone())).collect(),
                ),
            );
        }
        if !self.notes.is_empty() {
            m.insert(
                "notes".into(),
                Value::Arr(
                    self.notes.iter().map(|n| Value::Str(n.clone())).collect(),
                ),
            );
        }
        Value::Obj(m)
    }
}

impl ScenarioSpec {
    /// Parse from a JSON value tree (the shape both the TOML reader and
    /// `to_json` produce).
    pub fn from_json(v: &Value) -> Result<ScenarioSpec> {
        let m = map_of(v, "scenario")?;
        check_keys(
            m,
            &[
                "name", "title", "workload", "cluster", "study", "options",
                "resilience", "output",
            ],
            "scenario",
        )?;
        let name = opt_str(m, "name", "scenario")?.ok_or_else(|| {
            Error::Config("scenario: missing 'name'".into())
        })?;
        let title = opt_str(m, "title", "scenario")?.unwrap_or_else(|| name.clone());
        let workload = match m.get("workload") {
            Some(v) => WorkloadSpec::from_json(v)?,
            None => WorkloadSpec::Transformer(Transformer::t1()),
        };
        let cluster = match m.get("cluster") {
            Some(v) => cluster_from_json(v)?,
            None => presets::dgx_a100_1024(),
        };
        let study = Study::from_json(m.get("study").ok_or_else(|| {
            Error::Config("scenario: missing [study] section".into())
        })?)?;
        // cluster-compare takes its clusters from [study].clusters; a
        // [cluster] section would be silently ignored, so reject it.
        if matches!(study, Study::ClusterCompare { .. })
            && m.contains_key("cluster")
        {
            return Err(Error::Config(
                "scenario: cluster-compare studies name their clusters in \
                 [study].clusters; remove the [cluster] section"
                    .into(),
            ));
        }
        let options = match m.get("options") {
            Some(v) => OptionsSpec::from_json(v)?,
            None => OptionsSpec::default(),
        };
        let resilience = match m.get("resilience") {
            Some(v) => fault_model_from_json(v)?,
            None => FaultModel::none(),
        };
        let output = match m.get("output") {
            Some(v) => OutputSpec::from_json(v)?,
            None => OutputSpec::default(),
        };
        Ok(ScenarioSpec {
            name,
            title,
            workload,
            cluster,
            study,
            options,
            resilience,
            output,
        })
    }

    /// Serialize to the canonical JSON tree (fully resolved — presets are
    /// expanded). `from_json(to_json(spec)) == spec`.
    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Value::Str(self.name.clone()));
        m.insert("title".into(), Value::Str(self.title.clone()));
        m.insert("workload".into(), self.workload.to_json());
        // cluster-compare studies carry their clusters in [study]; a
        // cluster section is rejected on parse, so don't emit one.
        if !matches!(self.study, Study::ClusterCompare { .. }) {
            m.insert("cluster".into(), self.cluster.to_json());
        }
        m.insert("study".into(), self.study.to_json());
        m.insert("options".into(), self.options.to_json());
        // Emitted only when non-default so pre-resilience exports stay
        // byte-identical.
        if self.resilience != FaultModel::none() {
            m.insert("resilience".into(), fault_model_to_json(&self.resilience));
        }
        m.insert("output".into(), self.output.to_json());
        Value::Obj(m)
    }

    /// Parse from TOML or JSON text.
    pub fn parse_str(text: &str) -> Result<ScenarioSpec> {
        Self::from_json(&super::parse::parse_document(text)?)
    }

    /// Load from a file (TOML or JSON, auto-detected).
    pub fn load(path: &Path) -> Result<ScenarioSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{}: {e}", path.display())))?;
        Self::parse_str(&text).map_err(|e| {
            Error::Config(format!("{}: {e}", path.display()))
        })
    }

    /// Serialize as a TOML scenario file (the `scenario export` format).
    pub fn to_toml(&self) -> Result<String> {
        super::parse::to_toml(&self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::gb;

    #[test]
    fn minimal_spec_gets_defaults() {
        let s = ScenarioSpec::parse_str(
            "name = \"mini\"\n[study]\nkind = \"grid\"\n",
        )
        .unwrap();
        assert_eq!(s.title, "mini");
        assert_eq!(s.cluster, presets::dgx_a100_1024());
        assert!(matches!(
            s.workload,
            WorkloadSpec::Transformer(ref t) if t.name == "transformer-1t"
        ));
        assert_eq!(s.options, OptionsSpec::default());
        match &s.study {
            Study::Grid { strategies, .. } => {
                assert_eq!(
                    *strategies,
                    StrategyAxis::Pow2 {
                        min_mp: 1,
                        max_mp: None,
                        max_pp: 1
                    }
                );
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn pipeline_study_parses_and_roundtrips() {
        let s = ScenarioSpec::parse_str(
            "name = \"pipe\"\n[study]\nkind = \"pipeline\"\nmp = 8\n\
             pps = [1, 2, 4, 8]\nmicrobatches = [4, 8, 16]\n\
             schedules = [\"gpipe\", \"1f1b\"]\n\
             [options]\nmicrobatches = 16\nschedule = \"gpipe\"\n",
        )
        .unwrap();
        match &s.study {
            Study::Pipeline {
                mp,
                pps,
                microbatch_counts,
                schedules,
            } => {
                assert_eq!(*mp, 8);
                assert_eq!(pps, &[1, 2, 4, 8]);
                assert_eq!(microbatch_counts, &[4, 8, 16]);
                assert_eq!(schedules.len(), 2);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.options.microbatches, 16);
        assert_eq!(s.options.schedule, PipeSchedule::GPipe);
        let back = ScenarioSpec::parse_str(&s.to_toml().unwrap()).unwrap();
        assert_eq!(s, back);
        // Schedules default to both; empty axes are rejected.
        let d = ScenarioSpec::parse_str(
            "name = \"pipe\"\n[study]\nkind = \"pipeline\"\npps = [2]\n\
             microbatches = [8]\n",
        )
        .unwrap();
        assert!(matches!(
            d.study,
            Study::Pipeline { ref schedules, .. } if schedules.len() == 2
        ));
        for doc in [
            "name = \"p\"\n[study]\nkind = \"pipeline\"\npps = []\n\
             microbatches = [8]\n",
            "name = \"p\"\n[study]\nkind = \"pipeline\"\npps = [2]\n\
             microbatches = [0]\n",
            "name = \"p\"\n[study]\nkind = \"pipeline\"\npps = [2]\n\
             microbatches = [8]\nschedules = [\"zigzag\"]\n",
            "name = \"p\"\n[options]\nmicrobatches = 0\n\
             [study]\nkind = \"pipeline\"\npps = [2]\nmicrobatches = [8]\n",
            "name = \"p\"\n[study]\nkind = \"pipeline\"\nmp = 0\n\
             pps = [2]\nmicrobatches = [8]\n",
        ] {
            assert!(ScenarioSpec::parse_str(doc).is_err(), "{doc}");
        }
    }

    #[test]
    fn tier_mapping_study_parses_and_roundtrips() {
        let s = ScenarioSpec::parse_str(
            "name = \"tm\"\n[cluster]\npreset = \"tiered-het-64\"\n\
             [study]\nkind = \"tier-mapping\"\n\
             strategies = [\"MP8_DP8\", \"MP4_DP16\"]\n\
             mappings = [\"mp-inner\", \"dp-inner\"]\n",
        )
        .unwrap();
        match &s.study {
            Study::TierMapping {
                strategies,
                mappings,
            } => {
                assert_eq!(strategies.resolve(64).unwrap().len(), 2);
                assert_eq!(
                    mappings,
                    &[TierMapping::MpInner, TierMapping::DpInner]
                );
            }
            other => panic!("{other:?}"),
        }
        let back = ScenarioSpec::parse_str(&s.to_toml().unwrap()).unwrap();
        assert_eq!(s, back);
        // Mappings default to both; bad names are rejected.
        let d = ScenarioSpec::parse_str(
            "name = \"tm\"\n[study]\nkind = \"tier-mapping\"\n",
        )
        .unwrap();
        assert!(matches!(
            d.study,
            Study::TierMapping { ref mappings, .. } if mappings.len() == 2
        ));
        assert!(ScenarioSpec::parse_str(
            "name = \"tm\"\n[study]\nkind = \"tier-mapping\"\n\
             mappings = [\"inside-out\"]\n"
        )
        .is_err());
    }

    #[test]
    fn tier_mapping_option_parses_and_roundtrips() {
        let s = ScenarioSpec::parse_str(
            "name = \"tm\"\n[options]\ntier_mapping = \"dp-inner\"\n\
             [study]\nkind = \"grid\"\n",
        )
        .unwrap();
        assert_eq!(s.options.tier_mapping, TierMapping::DpInner);
        let back = ScenarioSpec::parse_str(&s.to_toml().unwrap()).unwrap();
        assert_eq!(s, back);
        // The default mapping is omitted from exports (legacy files stay
        // byte-identical).
        let plain = ScenarioSpec::parse_str(
            "name = \"tm\"\n[study]\nkind = \"grid\"\n",
        )
        .unwrap();
        assert!(!plain.to_toml().unwrap().contains("tier_mapping"));
        assert!(ScenarioSpec::parse_str(
            "name = \"tm\"\n[options]\ntier_mapping = \"sideways\"\n\
             [study]\nkind = \"grid\"\n"
        )
        .is_err());
    }

    #[test]
    fn max_pp_extends_the_strategy_axis() {
        let s = ScenarioSpec::parse_str(
            "name = \"x\"\n[study]\nkind = \"optimize\"\nmin_mp = 8\n\
             max_mp = 8\nmax_pp = 4\n",
        )
        .unwrap();
        match &s.study {
            Study::Optimize { strategies, .. } => {
                let v = strategies.resolve(1024).unwrap();
                assert_eq!(v.len(), 3); // pp = 1, 2, 4 at MP8
                assert!(v.iter().any(|st| st.pp == 4));
            }
            other => panic!("{other:?}"),
        }
        let back = ScenarioSpec::parse_str(&s.to_toml().unwrap()).unwrap();
        assert_eq!(s, back);
        assert!(ScenarioSpec::parse_str(
            "name = \"x\"\n[study]\nkind = \"grid\"\nmax_pp = 0\n"
        )
        .is_err());
    }

    #[test]
    fn workload_knob_overrides_apply() {
        let s = ScenarioSpec::parse_str(
            "name = \"x\"\n[workload]\nkind = \"transformer\"\n\
             preset = \"transformer-100m\"\nstacks = 24\nbatch = 4\n\
             [study]\nkind = \"grid\"\n",
        )
        .unwrap();
        match &s.workload {
            WorkloadSpec::Transformer(t) => {
                assert_eq!(t.stacks, 24);
                assert_eq!(t.batch, 4.0);
                assert_eq!(t.d_model, 768.0); // preset value kept
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn cluster_preset_with_overrides() {
        let s = ScenarioSpec::parse_str(
            "name = \"x\"\n[cluster]\npreset = \"baseline\"\nn_nodes = 256\n\
             expanded_capacity_gb = 200\nexpanded_bandwidth_gbps = 500\n\
             [study]\nkind = \"grid\"\n",
        )
        .unwrap();
        assert_eq!(s.cluster.n_nodes, 256);
        assert_eq!(s.cluster.node.expanded.capacity, gb(200.0));
        assert_eq!(s.cluster.node.expanded.bandwidth, gb(500.0));
    }

    #[test]
    fn unknown_keys_rejected_everywhere() {
        for doc in [
            "name = \"x\"\nbogus = 1\n[study]\nkind = \"grid\"\n",
            "name = \"x\"\n[study]\nkind = \"grid\"\nbogus = 1\n",
            "name = \"x\"\n[workload]\nbogus = 1\n[study]\nkind = \"grid\"\n",
            "name = \"x\"\n[options]\nbogus = 1\n[study]\nkind = \"grid\"\n",
            "name = \"x\"\n[output]\nbogus = 1\n[study]\nkind = \"grid\"\n",
            "name = \"x\"\n[cluster]\npreset = \"baseline\"\nbogus = 1\n\
             [study]\nkind = \"grid\"\n",
        ] {
            let e = ScenarioSpec::parse_str(doc).unwrap_err();
            assert!(e.to_string().contains("bogus"), "{doc}: {e}");
        }
    }

    #[test]
    fn missing_name_or_study_rejected() {
        assert!(ScenarioSpec::parse_str("[study]\nkind = \"grid\"\n").is_err());
        assert!(ScenarioSpec::parse_str("name = \"x\"\n").is_err());
        assert!(ScenarioSpec::parse_str(
            "name = \"x\"\n[study]\nkind = \"wat\"\n"
        )
        .is_err());
    }

    #[test]
    fn bad_values_rejected() {
        for doc in [
            // bad strategy label
            "name = \"x\"\n[study]\nkind = \"grid\"\n\
             strategies = [\"MP8DP8\"]\n",
            // bad zero stage
            "name = \"x\"\n[study]\nkind = \"grid\"\nzero_stages = [5]\n",
            // bad collective
            "name = \"x\"\n[study]\nkind = \"grid\"\n\
             collectives = [\"butterfly\"]\n",
            // bad backend
            "name = \"x\"\n[options]\nbackend = \"gpu\"\n\
             [study]\nkind = \"grid\"\n",
            // non-integer sizes
            "name = \"x\"\n[study]\nkind = \"cluster-size\"\n\
             sizes = [1.5]\n",
            // unknown preset
            "name = \"x\"\n[cluster]\npreset = \"Z9\"\n\
             [study]\nkind = \"grid\"\n",
        ] {
            assert!(ScenarioSpec::parse_str(doc).is_err(), "{doc}");
        }
    }

    #[test]
    fn cluster_compare_rejects_cluster_section() {
        let e = ScenarioSpec::parse_str(
            "name = \"x\"\n[cluster]\npreset = \"baseline\"\n\
             [study]\nkind = \"cluster-compare\"\nclusters = [\"A0\"]\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("cluster-compare"), "{e}");
        // Without the section it parses, and its JSON roundtrips (no
        // cluster key is emitted).
        let s = ScenarioSpec::parse_str(
            "name = \"x\"\n[study]\nkind = \"cluster-compare\"\n\
             clusters = [\"A0\"]\n",
        )
        .unwrap();
        let back = ScenarioSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn dlrm_typo_keys_rejected_in_workload_and_study() {
        let e = ScenarioSpec::parse_str(
            "name = \"x\"\n[workload]\nkind = \"dlrm\"\nemb_parms = 5\n\
             [study]\nkind = \"grid\"\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("emb_parms"), "{e}");
        let e = ScenarioSpec::parse_str(
            "name = \"x\"\n[study]\nkind = \"cluster-compare\"\n\
             clusters = [\"A0\"]\ndlrm = { emb_parms = 5 }\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("emb_parms"), "{e}");
    }

    #[test]
    fn inline_cluster_rejects_stray_keys() {
        let mut cluster = match presets::dgx_a100_64().to_json() {
            Value::Obj(m) => m,
            _ => unreachable!(),
        };
        // An override-style key on an inline cluster would otherwise be
        // dropped silently by ClusterConfig::from_json.
        cluster.insert("local_capacity_gb".into(), Value::Num(40.0));
        let mut doc = BTreeMap::new();
        doc.insert("name".into(), Value::Str("x".into()));
        doc.insert("cluster".into(), Value::Obj(cluster));
        let mut study = BTreeMap::new();
        study.insert("kind".into(), Value::Str("grid".into()));
        doc.insert("study".into(), Value::Obj(study));
        let e = ScenarioSpec::from_json(&Value::Obj(doc)).unwrap_err();
        assert!(e.to_string().contains("local_capacity_gb"), "{e}");
    }

    #[test]
    fn json_roundtrip_through_text() {
        let s = ScenarioSpec::parse_str(
            "name = \"rt\"\ntitle = \"Roundtrip\"\n\
             [workload]\nkind = \"gemm\"\nm = 65536\nk = 8192\nn = 8192\n\
             [cluster]\npreset = \"B1\"\n\
             [study]\nkind = \"grid\"\nstrategies = [\"MP1_DP8\"]\n\
             em_bandwidths_gbps = [250, 2039]\n\
             [options]\ninfinite_memory = true\nbackend = \"des\"\n\
             [output]\nformat = \"csv\"\nnormalize = \"best\"\n\
             footprint = true\nnotes = [\"a\", \"b\"]\n",
        )
        .unwrap();
        let text = s.to_json().to_string_pretty();
        let back =
            ScenarioSpec::from_json(&crate::util::json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn optimize_study_parses_and_roundtrips() {
        let s = ScenarioSpec::parse_str(
            "name = \"opt\"\n[study]\nkind = \"optimize\"\nmin_mp = 2\n\
             max_mp = 128\nem_bandwidths_gbps = [500, 2039]\n\
             collectives = [\"ring\", \"hierarchical\"]\ntop_k = 3\n",
        )
        .unwrap();
        match &s.study {
            Study::Optimize {
                top_k,
                em_bandwidths_gbps,
                collectives,
                ..
            } => {
                assert_eq!(*top_k, 3);
                assert_eq!(em_bandwidths_gbps, &[500.0, 2039.0]);
                assert_eq!(collectives.len(), 2);
            }
            other => panic!("{other:?}"),
        }
        let back = ScenarioSpec::parse_str(&s.to_toml().unwrap()).unwrap();
        assert_eq!(s, back);
        // top_k defaults to 5; zero is rejected.
        let d = ScenarioSpec::parse_str(
            "name = \"opt\"\n[study]\nkind = \"optimize\"\n",
        )
        .unwrap();
        assert!(matches!(d.study, Study::Optimize { top_k: 5, .. }));
        assert!(ScenarioSpec::parse_str(
            "name = \"opt\"\n[study]\nkind = \"optimize\"\ntop_k = 0\n"
        )
        .is_err());
    }

    #[test]
    fn optimize_threads_option_parses_and_roundtrips() {
        // threads defaults to None (= pool width)...
        let d = ScenarioSpec::parse_str(
            "name = \"opt\"\n[study]\nkind = \"optimize\"\n",
        )
        .unwrap();
        assert!(matches!(d.study, Study::Optimize { threads: None, .. }));
        // ...an explicit width parses and survives TOML export...
        let s = ScenarioSpec::parse_str(
            "name = \"opt\"\n[study]\nkind = \"optimize\"\nthreads = 4\n",
        )
        .unwrap();
        assert!(matches!(
            s.study,
            Study::Optimize {
                threads: Some(4),
                ..
            }
        ));
        let back = ScenarioSpec::parse_str(&s.to_toml().unwrap()).unwrap();
        assert_eq!(s, back);
        // ...and zero is rejected.
        assert!(ScenarioSpec::parse_str(
            "name = \"opt\"\n[study]\nkind = \"optimize\"\nthreads = 0\n"
        )
        .is_err());
    }

    #[test]
    fn optimize_objective_parses_and_roundtrips() {
        // objective defaults to time and is then not serialized...
        let d = ScenarioSpec::parse_str(
            "name = \"opt\"\n[study]\nkind = \"optimize\"\n",
        )
        .unwrap();
        assert!(matches!(
            d.study,
            Study::Optimize {
                objective: Objective::Time,
                ..
            }
        ));
        assert!(!d.to_toml().unwrap().contains("objective"));
        // ...goodput parses, roundtrips, and combines with [resilience].
        let s = ScenarioSpec::parse_str(
            "name = \"opt\"\n[resilience]\nmtbf_node_hours = 200\n\
             restart_s = 90\nstraggler_frac = 0.02\n\
             straggler_slowdown = 1.5\nseed = 7\n\
             [study]\nkind = \"optimize\"\nobjective = \"goodput\"\n",
        )
        .unwrap();
        assert!(matches!(
            s.study,
            Study::Optimize {
                objective: Objective::Goodput,
                ..
            }
        ));
        assert_eq!(s.resilience.mtbf_node_hours, 200.0);
        assert_eq!(s.resilience.restart_s, 90.0);
        assert_eq!(s.resilience.seed, 7);
        let back = ScenarioSpec::parse_str(&s.to_toml().unwrap()).unwrap();
        assert_eq!(s, back);
        // Unknown objectives and invalid fault models are rejected.
        assert!(ScenarioSpec::parse_str(
            "name = \"x\"\n[study]\nkind = \"optimize\"\n\
             objective = \"carbon\"\n"
        )
        .is_err());
        assert!(ScenarioSpec::parse_str(
            "name = \"x\"\n[resilience]\nstraggler_frac = 2.0\n\
             [study]\nkind = \"optimize\"\n"
        )
        .is_err());
        assert!(ScenarioSpec::parse_str(
            "name = \"x\"\n[resilience]\nbogus = 1\n\
             [study]\nkind = \"optimize\"\n"
        )
        .unwrap_err()
        .to_string()
        .contains("bogus"));
    }

    #[test]
    fn optimize_exec_knobs_parse_and_roundtrip() {
        // Absent knobs stay None and are not serialized, so exports
        // predating them are byte-identical.
        let d = ScenarioSpec::parse_str(
            "name = \"opt\"\n[study]\nkind = \"optimize\"\n",
        )
        .unwrap();
        assert!(matches!(
            d.study,
            Study::Optimize {
                deadline_s: None,
                checkpoint: None,
                checkpoint_every_s: None,
                ..
            }
        ));
        let toml = d.to_toml().unwrap();
        assert!(!toml.contains("deadline_s"));
        assert!(!toml.contains("checkpoint"));
        // Explicit knobs parse and survive TOML export.
        let s = ScenarioSpec::parse_str(
            "name = \"opt\"\n[study]\nkind = \"optimize\"\n\
             deadline_s = 30\ncheckpoint = \"/tmp/ck.json\"\n\
             checkpoint_every_s = 0\n",
        )
        .unwrap();
        match &s.study {
            Study::Optimize {
                deadline_s,
                checkpoint,
                checkpoint_every_s,
                ..
            } => {
                assert_eq!(*deadline_s, Some(30.0));
                assert_eq!(checkpoint.as_deref(), Some("/tmp/ck.json"));
                assert_eq!(*checkpoint_every_s, Some(0.0));
            }
            other => panic!("{other:?}"),
        }
        let back = ScenarioSpec::parse_str(&s.to_toml().unwrap()).unwrap();
        assert_eq!(s, back);
        // Negative budgets and an interval without a checkpoint path
        // are rejected.
        assert!(ScenarioSpec::parse_str(
            "name = \"x\"\n[study]\nkind = \"optimize\"\ndeadline_s = -1\n"
        )
        .is_err());
        assert!(ScenarioSpec::parse_str(
            "name = \"x\"\n[study]\nkind = \"optimize\"\n\
             checkpoint_every_s = 5\n"
        )
        .is_err());
        // Resilience sweeps accept a deadline too.
        let r = ScenarioSpec::parse_str(
            "name = \"r\"\n[study]\nkind = \"resilience\"\n\
             mtbf_hours = [500]\ndeadline_s = 10\n",
        )
        .unwrap();
        assert!(matches!(
            r.study,
            Study::Resilience {
                deadline_s: Some(d),
                ..
            } if d == 10.0
        ));
        let back = ScenarioSpec::parse_str(&r.to_toml().unwrap()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn resilience_study_parses_and_roundtrips() {
        let s = ScenarioSpec::parse_str(
            "name = \"res\"\n[resilience]\nrestart_s = 120\n\
             mtbf_node_hours = 500\n\
             [study]\nkind = \"resilience\"\nstrategies = \"pow2\"\n\
             min_mp = 2\nmax_mp = 128\nmtbf_hours = [2000, 500, 50]\n\
             em_bandwidth_gbps = 2039\n",
        )
        .unwrap();
        match &s.study {
            Study::Resilience {
                mtbf_hours,
                em_bandwidth_gbps,
                ..
            } => {
                assert_eq!(mtbf_hours, &[2000.0, 500.0, 50.0]);
                assert_eq!(*em_bandwidth_gbps, Some(2039.0));
            }
            other => panic!("{other:?}"),
        }
        let back = ScenarioSpec::parse_str(&s.to_toml().unwrap()).unwrap();
        assert_eq!(s, back);
        // The MTBF sweep is required, non-empty, and positive.
        for doc in [
            "name = \"r\"\n[study]\nkind = \"resilience\"\n",
            "name = \"r\"\n[study]\nkind = \"resilience\"\n\
             mtbf_hours = []\n",
            "name = \"r\"\n[study]\nkind = \"resilience\"\n\
             mtbf_hours = [-5]\n",
        ] {
            assert!(ScenarioSpec::parse_str(doc).is_err(), "{doc}");
        }
    }

    #[test]
    fn toml_export_roundtrips() {
        let s = ScenarioSpec::parse_str(
            "name = \"rt\"\n[study]\nkind = \"packing\"\ninstances = 8\n\
             packings = [32, 16, 8]\nem_bandwidths_gbps = [250, 500]\n\
             [workload]\nkind = \"dlrm\"\n",
        )
        .unwrap();
        let toml = s.to_toml().unwrap();
        let back = ScenarioSpec::parse_str(&toml).unwrap();
        assert_eq!(s, back);
    }
}
