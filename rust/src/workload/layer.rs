//! Layer and workload representation.
//!
//! Every layer carries, for each training phase (forward pass FP, input
//! gradient IG, weight gradient WG):
//!   * compute quantities — FLOPs plus the GEMM operand byte sizes (U, V, W)
//!     consumed by the tiling traffic model (paper SIII-C2), and
//!   * an optional communication collective with payload size and scope.
//!
//! A layer also has a `repeat` multiplicity so the N identical encoder
//! stacks of a Transformer are encoded once (operand sizes must stay
//! per-instance for the `ceil(U/S)` tiling term to stay meaningful).

/// Training phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Forward pass.
    Fp,
    /// Backward: input gradients (dL/dX).
    Ig,
    /// Backward: weight gradients (dL/dW).
    Wg,
}

impl Phase {
    /// All three phases, FP first.
    pub const ALL: [Phase; 3] = [Phase::Fp, Phase::Ig, Phase::Wg];
}

/// Collective type (matches the artifact ABI codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Collective {
    /// No communication.
    None,
    /// All-reduce.
    AllReduce,
    /// All-to-all (personalized exchange).
    AllToAll,
    /// All-gather.
    AllGather,
    /// Reduce-scatter.
    ReduceScatter,
}

impl Collective {
    /// ABI code (see python/compile/kernels/layout.py).
    pub fn code(self) -> f64 {
        match self {
            Collective::None => 0.0,
            Collective::AllReduce => 1.0,
            Collective::AllToAll => 2.0,
            Collective::AllGather => 3.0,
            Collective::ReduceScatter => 4.0,
        }
    }
}

/// Which node group a collective spans.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommScope {
    /// The model-parallel group (consecutive nodes).
    Mp,
    /// The data-parallel group (strided across MP groups).
    Dp,
    /// Every node in the job.
    All,
}

/// One communication collective attached to a layer phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Comm {
    /// Collective type.
    pub collective: Collective,
    /// Payload bytes per participant.
    pub bytes: f64,
    /// Node group the collective spans.
    pub scope: CommScope,
}

impl Comm {
    /// No communication.
    pub fn none() -> Comm {
        Comm {
            collective: Collective::None,
            bytes: 0.0,
            scope: CommScope::Mp,
        }
    }

    /// All-reduce over a scope.
    pub fn allreduce(bytes: f64, scope: CommScope) -> Comm {
        Comm {
            collective: Collective::AllReduce,
            bytes,
            scope,
        }
    }

    /// All-to-all over a scope.
    pub fn alltoall(bytes: f64, scope: CommScope) -> Comm {
        Comm {
            collective: Collective::AllToAll,
            bytes,
            scope,
        }
    }
}

/// The computational body of a layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LayerOp {
    /// GEMM of an (m x k) activation by a (k x n) weight, fp16.
    Gemm { m: f64, k: f64, n: f64 },
    /// Embedding-table lookup: `rows` gathers of `width`-wide vectors
    /// (paper: layers not expressible as GEMMs carry explicit op/byte
    /// counts).
    Lookup { rows: f64, width: f64 },
    /// Element-wise op over `elems` elements, `ops` FLOPs each.
    Elementwise { elems: f64, ops: f64 },
    /// Optimizer weight update over `params` parameters streaming `bytes`
    /// of parameter/gradient/optimizer state through memory in the WG
    /// phase. Purely bandwidth-bound — the term that makes low-MP
    /// configurations memory-bound in Fig. 8.
    WeightUpdate { params: f64, bytes: f64 },
    /// Opaque per-phase quantities `[FP, IG, WG]` — produced when parsing
    /// workload trace files, which flatten ops to raw records.
    Raw([PhaseQuantities; 3]),
}

/// Per-phase compute quantities consumed by the cost model.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PhaseQuantities {
    /// Floating-point operations.
    pub flops: f64,
    /// First GEMM operand bytes (0 for non-GEMM layers).
    pub u: f64,
    /// Second GEMM operand bytes (0 for non-GEMM layers).
    pub v: f64,
    /// Output / streamed bytes.
    pub w: f64,
}

impl PhaseQuantities {
    /// Minimum memory traffic if every byte moved exactly once.
    pub fn min_traffic(&self) -> f64 {
        self.u + self.v + self.w
    }
}

/// Bytes per fp16 element.
pub const FP16: f64 = 2.0;

impl LayerOp {
    /// Compute quantities for a phase.
    ///
    /// GEMM: FP is `Y = X(mxk) . W(kxn)`; IG is `dX = dY(mxn) . W^T(nxk)`;
    /// WG is `dW = X^T(kxm) . dY(mxn)`. Each moves the two inputs and one
    /// output; all are `2mkn` FLOPs.
    ///
    /// Lookup: FP gathers rows (read + write, one op/element); IG is free;
    /// WG scatters gradient rows back (table update).
    ///
    /// Elementwise: FP and IG touch the data once each (read + write); no
    /// weights, so WG is free.
    pub fn quantities(&self, phase: Phase) -> PhaseQuantities {
        match *self {
            LayerOp::Gemm { m, k, n } => {
                let flops = 2.0 * m * k * n;
                let (u, v, w) = match phase {
                    Phase::Fp => (m * k, k * n, m * n),
                    Phase::Ig => (m * n, n * k, m * k),
                    Phase::Wg => (k * m, m * n, k * n),
                };
                PhaseQuantities {
                    flops,
                    u: u * FP16,
                    v: v * FP16,
                    w: w * FP16,
                }
            }
            LayerOp::Lookup { rows, width } => match phase {
                Phase::Fp => PhaseQuantities {
                    flops: rows * width,
                    u: 0.0,
                    v: 0.0,
                    w: 2.0 * rows * width * FP16,
                },
                Phase::Ig => PhaseQuantities::default(),
                Phase::Wg => PhaseQuantities {
                    flops: rows * width,
                    u: 0.0,
                    v: 0.0,
                    w: 2.0 * rows * width * FP16,
                },
            },
            LayerOp::Elementwise { elems, ops } => match phase {
                Phase::Fp | Phase::Ig => PhaseQuantities {
                    flops: elems * ops,
                    u: 0.0,
                    v: 0.0,
                    w: 2.0 * elems * FP16,
                },
                Phase::Wg => PhaseQuantities::default(),
            },
            LayerOp::WeightUpdate { params, bytes } => match phase {
                Phase::Fp | Phase::Ig => PhaseQuantities::default(),
                // ~4 FLOPs/param for an Adam step; traffic dominates.
                Phase::Wg => PhaseQuantities {
                    flops: 4.0 * params,
                    u: 0.0,
                    v: 0.0,
                    w: bytes,
                },
            },
            LayerOp::Raw(q) => match phase {
                Phase::Fp => q[0],
                Phase::Ig => q[1],
                Phase::Wg => q[2],
            },
        }
    }

    /// Number of (weight) parameters this op contributes to the model.
    pub fn params(&self) -> f64 {
        match *self {
            LayerOp::Gemm { k, n, .. } => k * n,
            LayerOp::Lookup { rows: _, width: _ } => 0.0,
            LayerOp::Elementwise { .. } => 0.0,
            LayerOp::WeightUpdate { .. } => 0.0,
            LayerOp::Raw(_) => 0.0,
        }
    }
}

/// One layer of a decomposed model.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    /// Human-readable name ("Q proj", "MLP-1", ...).
    pub name: String,
    /// The compute body (per instance).
    pub op: LayerOp,
    /// Slot multiplicity: how many identical instances of this layer the
    /// model contains (e.g. 128 Transformer stacks).
    pub repeat: f64,
    /// Extra parameters not captured by `op` (embedding tables).
    pub extra_params: f64,
    /// Communication in the forward pass.
    pub comm_fp: Comm,
    /// Communication in the input-gradient phase.
    pub comm_ig: Comm,
    /// Communication in the weight-gradient phase.
    pub comm_wg: Comm,
}

impl Layer {
    /// A compute-only layer.
    pub fn new(name: &str, op: LayerOp, repeat: f64) -> Layer {
        Layer {
            name: name.to_string(),
            op,
            repeat,
            extra_params: 0.0,
            comm_fp: Comm::none(),
            comm_ig: Comm::none(),
            comm_wg: Comm::none(),
        }
    }

    /// Communication for a phase.
    pub fn comm(&self, phase: Phase) -> Comm {
        match phase {
            Phase::Fp => self.comm_fp,
            Phase::Ig => self.comm_ig,
            Phase::Wg => self.comm_wg,
        }
    }

    /// Parameters contributed (per node), including all repeats.
    pub fn params(&self) -> f64 {
        (self.op.params() + self.extra_params) * self.repeat
    }

    /// Activation elements produced per instance (for residual-state
    /// footprint estimation).
    pub fn activation_elems(&self) -> f64 {
        match self.op {
            LayerOp::Gemm { m, n, .. } => m * n,
            LayerOp::Lookup { rows, width } => rows * width,
            LayerOp::Elementwise { elems, .. } => elems,
            LayerOp::WeightUpdate { .. } => 0.0,
            LayerOp::Raw(q) => q[0].w / FP16 / 2.0,
        }
    }
}

/// One (layer, repeat-share) slice of a pipeline-stage partition: the
/// stage holds `repeat` instances' worth of layer `layer` (fractional when
/// a repeated layer straddles a stage boundary).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageSlice {
    /// Index into [`Workload::layers`].
    pub layer: usize,
    /// Instance multiplicity assigned to this stage (may be fractional).
    pub repeat: f64,
}

/// A decomposed model: named layer list plus bookkeeping, the unit of work
/// the cost model and simulator consume.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    /// Model name ("transformer-1t@mp8_dp128").
    pub name: String,
    /// Decomposed layers in forward order. With pipeline parallelism
    /// (`pp > 1`) this is still the full MP-shard layer list; each node
    /// holds only its stage's contiguous slice (see
    /// [`Workload::stage_partition`]).
    pub layers: Vec<Layer>,
    /// MP degree the decomposition was built for.
    pub mp: usize,
    /// DP degree the decomposition was built for.
    pub dp: usize,
    /// Pipeline-parallel degree (contiguous layer stages); `1` = no
    /// pipeline parallelism.
    pub pp: usize,
    /// Total nodes the decomposition occupies. For MP x DP x PP workloads
    /// this is `mp * dp * pp`; for DLRM-style hybrid parallelism
    /// (embeddings sharded over all nodes AND MLPs replicated over all
    /// nodes) it is the node count itself.
    pub nodes: usize,
    /// Total model parameters (across all MP shards, one DP replica).
    pub total_params: f64,
}

impl Workload {
    /// Per-node parameter count (the MP shard).
    pub fn params_per_node(&self) -> f64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Total FLOPs per node per iteration (all phases, all layers).
    pub fn total_flops(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| {
                l.repeat
                    * Phase::ALL
                        .iter()
                        .map(|&p| l.op.quantities(p).flops)
                        .sum::<f64>()
            })
            .sum()
    }

    /// Activation working-memory elements (largest single layer's output;
    /// intermediate activations between checkpoints — ZeRO-Infinity's AWM).
    pub fn activation_working_elems(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.activation_elems())
            .fold(0.0, f64::max)
    }

    /// Number of distinct layer slots (ABI rows needed).
    pub fn n_slots(&self) -> usize {
        self.layers.len()
    }

    /// Contiguous pipeline-stage partition of the layer list, balanced by
    /// FLOPs (all three phases, including the optimizer update's).
    ///
    /// The layer sequence is treated as a continuous mass of
    /// `repeat x per-instance-FLOPs` per layer and cut at the `pp - 1`
    /// equal-mass boundaries; a repeated layer that straddles a boundary
    /// is split with fractional repeats (the cost models already support
    /// fractional multiplicities). Zero-FLOP layers attach to the stage
    /// the cursor is in. At `pp = 1` this is the identity partition —
    /// one stage holding every layer at its full repeat.
    pub fn stage_partition(&self) -> Vec<Vec<StageSlice>> {
        let pp = self.pp.max(1);
        let per_rep: Vec<f64> = self
            .layers
            .iter()
            .map(|l| {
                Phase::ALL
                    .iter()
                    .map(|&p| l.op.quantities(p).flops)
                    .sum::<f64>()
            })
            .collect();
        if pp == 1 {
            return vec![self
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| StageSlice {
                    layer: i,
                    repeat: l.repeat,
                })
                .collect()];
        }
        let total: f64 = self
            .layers
            .iter()
            .zip(&per_rep)
            .map(|(l, &f)| l.repeat * f)
            .sum();
        let mut stages: Vec<Vec<StageSlice>> = vec![Vec::new(); pp];
        if total <= 0.0 {
            // Degenerate (no compute anywhere): everything in stage 0.
            stages[0] = self
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| StageSlice {
                    layer: i,
                    repeat: l.repeat,
                })
                .collect();
            return stages;
        }
        let mut s = 0usize;
        let mut cum = 0.0f64;
        for (i, l) in self.layers.iter().enumerate() {
            let f = per_rep[i];
            if f <= 0.0 || l.repeat <= 0.0 {
                stages[s].push(StageSlice {
                    layer: i,
                    repeat: l.repeat,
                });
                continue;
            }
            let mut left = l.repeat;
            while left > 0.0 {
                let boundary = total * (s + 1) as f64 / pp as f64;
                let room = boundary - cum;
                if s + 1 < pp && left * f > room {
                    // Split at the stage boundary.
                    let take = (room / f).max(0.0);
                    if take > 0.0 {
                        stages[s].push(StageSlice {
                            layer: i,
                            repeat: take,
                        });
                    }
                    cum = boundary;
                    left -= take;
                    s += 1;
                } else {
                    stages[s].push(StageSlice { layer: i, repeat: left });
                    cum += left * f;
                    left = 0.0;
                }
            }
        }
        stages
    }

    /// Activation bytes crossing each stage boundary of a partition
    /// (length `stages.len() - 1`): the output of the last
    /// activation-producing layer of each stage, for the full mini-batch,
    /// fp16. Per-microbatch payloads are this divided by the microbatch
    /// count.
    pub fn stage_boundary_bytes(&self, stages: &[Vec<StageSlice>]) -> Vec<f64> {
        (0..stages.len().saturating_sub(1))
            .map(|s| {
                stages[s]
                    .iter()
                    .rev()
                    .map(|sl| self.layers[sl.layer].activation_elems() * FP16)
                    .find(|&b| b > 0.0)
                    .unwrap_or(0.0)
            })
            .collect()
    }

    /// Cache fingerprint: FNV-1a over everything the two-stage derive
    /// consumes — names (they flow into diagnostics), the
    /// (MP, DP, PP, nodes) shape, parameter totals, and every layer's
    /// per-phase quantities,
    /// activation footprint, and communication. Two workloads with equal
    /// fingerprints decompose identically, which is what lets the
    /// coordinator's derive cache share one decomposition across a sweep.
    pub fn fingerprint(&self) -> u64 {
        fn eat_byte(h: &mut u64, b: u8) {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100000001b3);
        }
        fn eat(h: &mut u64, x: f64) {
            for b in x.to_bits().to_le_bytes() {
                eat_byte(h, b);
            }
        }
        fn eat_str(h: &mut u64, s: &str) {
            for b in s.as_bytes() {
                eat_byte(h, *b);
            }
            // Terminator so "ab"+"c" and "a"+"bc" differ.
            eat_byte(h, 0xff);
        }
        let mut h: u64 = 0xcbf29ce484222325;
        eat_str(&mut h, &self.name);
        eat(&mut h, self.mp as f64);
        eat(&mut h, self.dp as f64);
        eat(&mut h, self.pp as f64);
        eat(&mut h, self.nodes as f64);
        eat(&mut h, self.total_params);
        let scope_code = |s: CommScope| match s {
            CommScope::Mp => 0.0,
            CommScope::Dp => 1.0,
            CommScope::All => 2.0,
        };
        for l in &self.layers {
            eat_str(&mut h, &l.name);
            eat(&mut h, l.repeat);
            eat(&mut h, l.activation_elems());
            for phase in Phase::ALL {
                let q = l.op.quantities(phase);
                eat(&mut h, q.flops);
                eat(&mut h, q.u);
                eat(&mut h, q.v);
                eat(&mut h, q.w);
                let c = l.comm(phase);
                eat(&mut h, c.collective.code());
                eat(&mut h, c.bytes);
                eat(&mut h, scope_code(c.scope));
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_quantities_fp() {
        let op = LayerOp::Gemm {
            m: 4.0,
            k: 8.0,
            n: 16.0,
        };
        let q = op.quantities(Phase::Fp);
        assert_eq!(q.flops, 2.0 * 4.0 * 8.0 * 16.0);
        assert_eq!(q.u, 4.0 * 8.0 * FP16);
        assert_eq!(q.v, 8.0 * 16.0 * FP16);
        assert_eq!(q.w, 4.0 * 16.0 * FP16);
    }

    #[test]
    fn gemm_phases_same_flops_different_operands() {
        let op = LayerOp::Gemm {
            m: 3.0,
            k: 5.0,
            n: 7.0,
        };
        let fp = op.quantities(Phase::Fp);
        let ig = op.quantities(Phase::Ig);
        let wg = op.quantities(Phase::Wg);
        assert_eq!(fp.flops, ig.flops);
        assert_eq!(fp.flops, wg.flops);
        // IG output is the input-activation gradient (m x k).
        assert_eq!(ig.w, 3.0 * 5.0 * FP16);
        // WG output is the weight gradient (k x n).
        assert_eq!(wg.w, 5.0 * 7.0 * FP16);
    }

    #[test]
    fn lookup_has_no_ig() {
        let op = LayerOp::Lookup {
            rows: 100.0,
            width: 64.0,
        };
        assert_eq!(op.quantities(Phase::Ig), PhaseQuantities::default());
        assert!(op.quantities(Phase::Fp).w > 0.0);
        assert!(op.quantities(Phase::Wg).w > 0.0);
    }

    #[test]
    fn elementwise_has_no_wg() {
        let op = LayerOp::Elementwise {
            elems: 1000.0,
            ops: 2.0,
        };
        assert_eq!(op.quantities(Phase::Wg), PhaseQuantities::default());
        assert_eq!(op.quantities(Phase::Fp).flops, 2000.0);
    }

    #[test]
    fn gemm_params_are_weight_matrix() {
        let op = LayerOp::Gemm {
            m: 10.0,
            k: 8.0,
            n: 16.0,
        };
        assert_eq!(op.params(), 128.0);
    }

    #[test]
    fn layer_params_scale_with_repeat() {
        let mut l = Layer::new(
            "mlp",
            LayerOp::Gemm {
                m: 2.0,
                k: 4.0,
                n: 8.0,
            },
            3.0,
        );
        assert_eq!(l.params(), 96.0);
        l.extra_params = 10.0;
        assert_eq!(l.params(), (32.0 + 10.0) * 3.0);
    }

    #[test]
    fn workload_aggregates() {
        let w = Workload {
            name: "test".into(),
            layers: vec![
                Layer::new(
                    "a",
                    LayerOp::Gemm {
                        m: 2.0,
                        k: 2.0,
                        n: 2.0,
                    },
                    2.0,
                ),
                Layer::new(
                    "b",
                    LayerOp::Elementwise {
                        elems: 100.0,
                        ops: 1.0,
                    },
                    1.0,
                ),
            ],
            mp: 1,
            dp: 1,
            pp: 1,
            nodes: 1,
            total_params: 8.0,
        };
        assert_eq!(w.params_per_node(), 8.0);
        // GEMM: 16 flops x 3 phases x repeat 2 = 96; EW: 100 x 2 phases.
        assert_eq!(w.total_flops(), 96.0 + 200.0);
        assert_eq!(w.n_slots(), 2);
        assert_eq!(w.activation_working_elems(), 100.0);
    }

    #[test]
    fn workload_fingerprint_distinguishes_content() {
        let base = Workload {
            name: "test".into(),
            layers: vec![Layer::new(
                "a",
                LayerOp::Gemm {
                    m: 2.0,
                    k: 2.0,
                    n: 2.0,
                },
                2.0,
            )],
            mp: 2,
            dp: 4,
            pp: 1,
            nodes: 8,
            total_params: 8.0,
        };
        assert_eq!(base.fingerprint(), base.clone().fingerprint());
        let mut renamed = base.clone();
        renamed.name = "other".into();
        assert_ne!(base.fingerprint(), renamed.fingerprint());
        let mut reshaped = base.clone();
        reshaped.mp = 4;
        reshaped.dp = 2;
        assert_ne!(base.fingerprint(), reshaped.fingerprint());
        let mut piped = base.clone();
        piped.pp = 2;
        assert_ne!(base.fingerprint(), piped.fingerprint());
        let mut recomm = base.clone();
        recomm.layers[0].comm_wg =
            Comm::allreduce(16.0, CommScope::Dp);
        assert_ne!(base.fingerprint(), recomm.fingerprint());
    }

    fn staged_workload(pp: usize) -> Workload {
        Workload {
            name: "staged".into(),
            layers: vec![
                Layer::new(
                    "stack",
                    LayerOp::Gemm {
                        m: 8.0,
                        k: 8.0,
                        n: 8.0,
                    },
                    16.0,
                ),
                Layer::new(
                    "head",
                    LayerOp::Gemm {
                        m: 8.0,
                        k: 8.0,
                        n: 16.0,
                    },
                    1.0,
                ),
            ],
            mp: 1,
            dp: 1,
            pp,
            nodes: pp,
            total_params: 100.0,
        }
    }

    #[test]
    fn stage_partition_identity_at_pp1() {
        let w = staged_workload(1);
        let stages = w.stage_partition();
        assert_eq!(stages.len(), 1);
        assert_eq!(stages[0].len(), 2);
        assert_eq!(stages[0][0], StageSlice { layer: 0, repeat: 16.0 });
        assert_eq!(stages[0][1], StageSlice { layer: 1, repeat: 1.0 });
    }

    #[test]
    fn stage_partition_balances_flops_and_conserves_repeats() {
        for pp in [2usize, 3, 4, 8] {
            let w = staged_workload(pp);
            let stages = w.stage_partition();
            assert_eq!(stages.len(), pp);
            let flops3 = |i: usize| -> f64 {
                Phase::ALL
                    .iter()
                    .map(|&p| w.layers[i].op.quantities(p).flops)
                    .sum()
            };
            let total: f64 =
                (0..2).map(|i| w.layers[i].repeat * flops3(i)).sum();
            let mut per_layer = [0.0f64; 2];
            for (s, slices) in stages.iter().enumerate() {
                assert!(!slices.is_empty(), "pp={pp}: stage {s} empty");
                let mass: f64 =
                    slices.iter().map(|sl| sl.repeat * flops3(sl.layer)).sum();
                assert!(
                    (mass - total / pp as f64).abs() < 1e-6 * total,
                    "pp={pp} stage {s}: mass {mass} vs {}",
                    total / pp as f64
                );
                for sl in slices {
                    per_layer[sl.layer] += sl.repeat;
                }
            }
            assert!((per_layer[0] - 16.0).abs() < 1e-9);
            assert!((per_layer[1] - 1.0).abs() < 1e-9);
            // Contiguity: layer indices never decrease across stages.
            let flat: Vec<usize> = stages
                .iter()
                .flat_map(|s| s.iter().map(|sl| sl.layer))
                .collect();
            assert!(flat.windows(2).all(|w| w[0] <= w[1]), "pp={pp}");
        }
    }

    #[test]
    fn stage_boundary_bytes_use_last_activation() {
        let w = staged_workload(4);
        let stages = w.stage_partition();
        let bytes = w.stage_boundary_bytes(&stages);
        assert_eq!(bytes.len(), 3);
        // Every boundary inside the repeated stack carries its 8x8 output.
        for b in &bytes {
            assert_eq!(*b, 8.0 * 8.0 * FP16);
        }
    }

    #[test]
    fn min_traffic_sums_operands() {
        let q = PhaseQuantities {
            flops: 0.0,
            u: 1.0,
            v: 2.0,
            w: 3.0,
        };
        assert_eq!(q.min_traffic(), 6.0);
    }
}
