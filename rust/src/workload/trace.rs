//! ASTRA-SIM-style workload input files (paper SIV-B: "the workload input
//! file must describe ... number of floating-point operations, data volume,
//! communication collective, and communication volume" per layer).
//!
//! Text format, one layer per line:
//!
//! ```text
//! # comet-workload v1 <name> mp=<mp> dp=<dp> params=<total>
//! <layer-name> <repeat> \
//!   fp <flops> <u> <v> <w> <collective> <bytes> <scope> \
//!   ig <flops> <u> <v> <w> <collective> <bytes> <scope> \
//!   wg <flops> <u> <v> <w> <collective> <bytes> <scope>
//! ```
//!
//! Layer names use `_` in place of spaces. The layer op is flattened into
//! raw per-phase quantities — this is the exact information the cost model
//! consumes, and matches ASTRA-SIM's layer-record philosophy.

use super::layer::{
    Collective, Comm, CommScope, Layer, LayerOp, Phase, Workload,
};
use crate::error::{Error, Result};

/// Serialize a workload to the trace format.
pub fn emit(w: &Workload) -> String {
    let mut out = format!(
        "# comet-workload v1 {} mp={} dp={} pp={} nodes={} params={}\n",
        w.name.replace(' ', "_"),
        w.mp,
        w.dp,
        w.pp,
        w.nodes,
        w.total_params
    );
    for l in &w.layers {
        out.push_str(&l.name.replace(' ', "_"));
        out.push(' ');
        out.push_str(&format!("{}", l.repeat));
        for phase in Phase::ALL {
            let q = l.op.quantities(phase);
            let c = l.comm(phase);
            out.push_str(&format!(
                " {} {} {} {} {} {} {} {}",
                phase_tag(phase),
                q.flops,
                q.u,
                q.v,
                q.w,
                collective_tag(c.collective),
                c.bytes,
                scope_tag(c.scope),
            ));
        }
        out.push('\n');
    }
    out
}

/// Parse a trace back into a workload. Layer ops come back as opaque
/// [`LayerOp::Raw`] quantity records (the trace does not preserve GEMM
/// shapes, by design — the cost model never needs them).
pub fn parse(text: &str) -> Result<Workload> {
    let mut lines = text.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Config("empty trace".into()))?;
    let mut name = String::new();
    let (mut mp, mut dp, mut params) = (1usize, 1usize, 0.0f64);
    // pp defaults to 1 so pre-3D traces parse unchanged.
    let mut pp = 1usize;
    let mut nodes = 0usize;
    for (i, tok) in header.split_whitespace().enumerate() {
        match i {
            0 | 1 | 2 if tok == "#" || tok == "comet-workload" || tok == "v1" => {}
            3 => name = tok.to_string(),
            _ => {
                if let Some(v) = tok.strip_prefix("mp=") {
                    mp = v.parse().map_err(|_| bad(header))?;
                } else if let Some(v) = tok.strip_prefix("dp=") {
                    dp = v.parse().map_err(|_| bad(header))?;
                } else if let Some(v) = tok.strip_prefix("pp=") {
                    pp = v.parse().map_err(|_| bad(header))?;
                } else if let Some(v) = tok.strip_prefix("nodes=") {
                    nodes = v.parse().map_err(|_| bad(header))?;
                } else if let Some(v) = tok.strip_prefix("params=") {
                    params = v.parse().map_err(|_| bad(header))?;
                }
            }
        }
    }
    if !header.starts_with("# comet-workload v1") {
        return Err(Error::Config(format!("bad trace header: {header}")));
    }

    let mut layers = Vec::new();
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() != 2 + 3 * 8 {
            return Err(bad(line));
        }
        let mut layer = Layer::new(toks[0], LayerOp::Raw(Default::default()), 1.0);
        layer.repeat = toks[1].parse().map_err(|_| bad(line))?;
        let mut raw = [Default::default(); 3];
        for (pi, phase) in Phase::ALL.iter().enumerate() {
            let base = 2 + pi * 8;
            if toks[base] != phase_tag(*phase) {
                return Err(bad(line));
            }
            let f: f64 = toks[base + 1].parse().map_err(|_| bad(line))?;
            let u: f64 = toks[base + 2].parse().map_err(|_| bad(line))?;
            let v: f64 = toks[base + 3].parse().map_err(|_| bad(line))?;
            let w: f64 = toks[base + 4].parse().map_err(|_| bad(line))?;
            raw[pi] = super::layer::PhaseQuantities { flops: f, u, v, w };
            let comm = Comm {
                collective: parse_collective(toks[base + 5]).ok_or_else(|| bad(line))?,
                bytes: toks[base + 6].parse().map_err(|_| bad(line))?,
                scope: parse_scope(toks[base + 7]).ok_or_else(|| bad(line))?,
            };
            match phase {
                Phase::Fp => layer.comm_fp = comm,
                Phase::Ig => layer.comm_ig = comm,
                Phase::Wg => layer.comm_wg = comm,
            }
        }
        layer.op = LayerOp::Raw(raw);
        layers.push(layer);
    }
    if nodes == 0 {
        nodes = mp * dp * pp;
    }
    Ok(Workload {
        name,
        layers,
        mp,
        dp,
        pp,
        nodes,
        total_params: params,
    })
}

fn bad(line: &str) -> Error {
    Error::Config(format!("bad trace line: {line}"))
}

fn phase_tag(p: Phase) -> &'static str {
    match p {
        Phase::Fp => "fp",
        Phase::Ig => "ig",
        Phase::Wg => "wg",
    }
}

fn collective_tag(c: Collective) -> &'static str {
    match c {
        Collective::None => "none",
        Collective::AllReduce => "allreduce",
        Collective::AllToAll => "alltoall",
        Collective::AllGather => "allgather",
        Collective::ReduceScatter => "reducescatter",
    }
}

fn parse_collective(s: &str) -> Option<Collective> {
    Some(match s {
        "none" => Collective::None,
        "allreduce" => Collective::AllReduce,
        "alltoall" => Collective::AllToAll,
        "allgather" => Collective::AllGather,
        "reducescatter" => Collective::ReduceScatter,
        _ => return None,
    })
}

fn scope_tag(s: CommScope) -> &'static str {
    match s {
        CommScope::Mp => "mp",
        CommScope::Dp => "dp",
        CommScope::All => "all",
    }
}

fn parse_scope(s: &str) -> Option<CommScope> {
    Some(match s {
        "mp" => CommScope::Mp,
        "dp" => CommScope::Dp,
        "all" => CommScope::All,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::Strategy;
    use crate::workload::transformer::Transformer;

    #[test]
    fn roundtrip_preserves_quantities() {
        let w = Transformer::t1()
            .build(&Strategy::new(8, 128).unwrap())
            .unwrap();
        let text = emit(&w);
        let back = parse(&text).unwrap();
        assert_eq!(back.layers.len(), w.layers.len());
        assert_eq!(back.mp, 8);
        assert_eq!(back.dp, 128);
        assert_eq!(back.pp, 1);
        for (a, b) in w.layers.iter().zip(back.layers.iter()) {
            assert_eq!(a.repeat, b.repeat);
            for phase in Phase::ALL {
                let qa = a.op.quantities(phase);
                let qb = b.op.quantities(phase);
                assert!((qa.flops - qb.flops).abs() <= qa.flops * 1e-12);
                assert_eq!(a.comm(phase).bytes, b.comm(phase).bytes);
                assert_eq!(a.comm(phase).collective, b.comm(phase).collective);
                assert_eq!(a.comm(phase).scope, b.comm(phase).scope);
            }
        }
    }

    #[test]
    fn roundtrip_preserves_pipeline_degree() {
        let w = Transformer::t1()
            .build(&Strategy::new_3d(8, 16, 8).unwrap())
            .unwrap();
        let text = emit(&w);
        assert!(text.contains(" pp=8 "), "{}", text.lines().next().unwrap());
        let back = parse(&text).unwrap();
        assert_eq!(back.pp, 8);
        assert_eq!(back.nodes, 1024);
        // A pre-3D header (no pp= token) parses with pp = 1.
        let legacy = "# comet-workload v1 old mp=2 dp=4 params=10\n";
        let old = parse(legacy).unwrap();
        assert_eq!(old.pp, 1);
        assert_eq!(old.nodes, 8);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(parse("garbage\n").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn rejects_truncated_line() {
        let w = Transformer::t100m()
            .build(&Strategy::new(2, 2).unwrap())
            .unwrap();
        let text = emit(&w);
        let mut lines: Vec<&str> = text.lines().collect();
        let truncated = &lines[1][..lines[1].len() / 2];
        lines[1] = truncated;
        assert!(parse(&lines.join("\n")).is_err());
    }
}
