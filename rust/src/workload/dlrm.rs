//! DLRM workload builder (paper SV-C, modeled after Rashidi et al.'s
//! ASTRA-SIM + ns3 DLRM case study).
//!
//! DLRM's parallelization is rigid (unlike the Transformer's MP/DP knob):
//! the huge embedding tables are sharded across all nodes (model-parallel,
//! exchanged via all-to-all in FP and IG), while the bottom/top MLPs are
//! replicated data-parallel (all-reduce of gradients in WG). The builder
//! therefore takes only a node count; `Strategy` is implied (MP = N for
//! embeddings, DP = N for MLPs).

use super::gemm::gemm;
use super::layer::{
    Comm, CommScope, Layer, LayerOp, PhaseQuantities, Workload, FP16,
};
use crate::error::{Error, Result};

/// DLRM hyper-parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Dlrm {
    /// Model name used in reports.
    pub name: String,
    /// Total embedding parameters (dominates model size).
    pub emb_params: f64,
    /// Embedding vector width.
    pub emb_dim: f64,
    /// Number of sparse-feature tables.
    pub tables: f64,
    /// Pooled lookups per sample per table.
    pub pooling: f64,
    /// Bottom-MLP layer widths (dense features -> emb_dim).
    pub bottom_mlp: Vec<f64>,
    /// Top-MLP layer widths (interaction output -> 1).
    pub top_mlp: Vec<f64>,
    /// Global batch (samples per iteration).
    pub global_batch: f64,
}

impl Dlrm {
    /// The 1.2-trillion-parameter DLRM of the paper's SV-C (Rashidi et al.
    /// Table V shape: wide embedding tables + small MLP stacks).
    pub fn dlrm_1_2t() -> Dlrm {
        Dlrm {
            name: "dlrm-1.2t".into(),
            emb_params: 1.2e12,
            emb_dim: 128.0,
            tables: 512.0,
            // Production DLRMs pool tens of rows per (sample, table)
            // (multi-hot categorical features); pooled-sum reduction
            // happens at the owning shard, so lookup *memory* traffic
            // scales with pooling while all-to-all traffic does not —
            // the balance that makes DLRM memory-bandwidth-sensitive
            // (paper SV-C) yet communication-dominated at large node
            // counts (Fig. 13a).
            pooling: 8.0,
            bottom_mlp: vec![13.0, 512.0, 256.0, 128.0],
            top_mlp: vec![479.0, 1024.0, 1024.0, 512.0, 256.0, 1.0],
            global_batch: 65_536.0,
        }
    }

    /// A small DLRM for examples/tests.
    pub fn small() -> Dlrm {
        Dlrm {
            name: "dlrm-small".into(),
            emb_params: 1.0e9,
            emb_dim: 64.0,
            tables: 26.0,
            pooling: 1.0,
            bottom_mlp: vec![13.0, 512.0, 64.0],
            top_mlp: vec![415.0, 512.0, 256.0, 1.0],
            global_batch: 2048.0,
        }
    }

    /// Total parameters (embeddings + MLPs).
    pub fn total_params(&self) -> f64 {
        self.emb_params + mlp_params(&self.bottom_mlp) + mlp_params(&self.top_mlp)
    }

    /// Decompose for a cluster of `nodes` nodes.
    pub fn build(&self, nodes: usize) -> Result<Workload> {
        if nodes == 0 || !nodes.is_power_of_two() {
            return Err(Error::Config(format!(
                "DLRM node count {nodes} must be a power of two"
            )));
        }
        let n = nodes as f64;
        let gb = self.global_batch;
        let local_batch = gb / n; // MLP data parallelism
        let mut layers = Vec::new();

        // --- sharded embedding lookup + all-to-all --------------------------
        // Each node owns tables/n tables and serves lookups for the WHOLE
        // global batch on its shard (gathering `pooling` rows per sample
        // per table and sum-pooling them locally), then exchanges the
        // POOLED vectors all-to-all so every node receives its local
        // batch's vectors for all tables.
        let rows_per_node = gb * self.pooling * self.tables / n;
        let pooled_per_node = gb * self.tables / n;
        let mut emb = Layer::new(
            "embedding-lookup",
            LayerOp::Lookup {
                rows: rows_per_node,
                width: self.emb_dim,
            },
            1.0,
        );
        emb.extra_params = self.emb_params / n;
        let a2a_bytes = pooled_per_node * self.emb_dim * FP16;
        emb.comm_fp = Comm::alltoall(a2a_bytes, CommScope::All);
        emb.comm_ig = Comm::alltoall(a2a_bytes, CommScope::All);
        layers.push(emb);

        // --- bottom MLP (data parallel) -------------------------------------
        push_mlp(
            &mut layers,
            "bottom-mlp",
            &self.bottom_mlp,
            local_batch,
            n,
        );

        // --- feature interaction (pairwise dot products) --------------------
        // A batched per-sample GEMM: each sample's (f x d) feature matrix
        // times its transpose. Every per-sample operand fits in SRAM, so
        // traffic is pure streaming (encoded as Raw quantities: the
        // input-stationary tiling model would otherwise charge phantom
        // re-reads of the batch-sized operands).
        let f = self.tables + 1.0; // embedding vectors + bottom-MLP output
        let int_flops = 2.0 * local_batch * f * self.emb_dim * f;
        let int_bytes =
            local_batch * (2.0 * f * self.emb_dim + f * f) * FP16;
        let int_q = PhaseQuantities {
            flops: int_flops,
            u: 0.0,
            v: 0.0,
            w: int_bytes,
        };
        layers.push(Layer::new(
            "interaction",
            LayerOp::Raw([int_q, int_q, int_q]),
            1.0,
        ));

        // --- top MLP (data parallel) ----------------------------------------
        push_mlp(&mut layers, "top-mlp", &self.top_mlp, local_batch, n);

        // --- optimizer update ------------------------------------------------
        // Embedding shard (sparse rows touched) + dense MLP params.
        let touched = (rows_per_node * self.emb_dim).min(self.emb_params / n);
        let dense = mlp_params(&self.bottom_mlp) + mlp_params(&self.top_mlp);
        let update_bytes = touched * 6.0 + dense * 22.0;
        layers.push(Layer::new(
            "weight-update",
            LayerOp::WeightUpdate {
                params: touched + dense,
                bytes: update_bytes,
            },
            1.0,
        ));

        Ok(Workload {
            name: format!("{}@n{}", self.name, nodes),
            layers,
            mp: nodes, // embedding sharding spans all nodes
            dp: nodes, // MLP replication spans all nodes
            pp: 1,     // DLRM parallelism is rigid: no pipeline axis
            nodes,
            total_params: self.total_params(),
        })
    }

    /// Per-node memory footprint in bytes for a cluster of `nodes`:
    /// fp16 embedding shard + optimizer state for the shard's rows +
    /// replicated dense MLPs (fp16 + full optimizer state).
    pub fn footprint_per_node(&self, nodes: usize) -> f64 {
        let shard = self.emb_params / nodes as f64;
        let dense = mlp_params(&self.bottom_mlp) + mlp_params(&self.top_mlp);
        shard * FP16 + dense * 16.0
    }
}

fn mlp_params(widths: &[f64]) -> f64 {
    widths.windows(2).map(|w| w[0] * w[1]).sum()
}

fn push_mlp(
    layers: &mut Vec<Layer>,
    prefix: &str,
    widths: &[f64],
    batch: f64,
    n_nodes: f64,
) {
    for (i, w) in widths.windows(2).enumerate() {
        let (k, n) = (w[0], w[1]);
        let mut l = Layer::new(&format!("{prefix}-{i}"), gemm(batch, k, n), 1.0);
        // Replicated MLP: DP all-reduce of the full weight gradient.
        l.comm_wg = Comm {
            collective: super::layer::Collective::AllReduce,
            bytes: k * n * FP16,
            scope: CommScope::All,
        };
        let _ = n_nodes;
        layers.push(l);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Phase;

    #[test]
    fn dlrm_is_1_2t() {
        let d = Dlrm::dlrm_1_2t();
        let p = d.total_params();
        assert!((1.15e12..1.25e12).contains(&p), "params {p:.3e}");
    }

    #[test]
    fn build_rejects_bad_node_count() {
        assert!(Dlrm::dlrm_1_2t().build(0).is_err());
        assert!(Dlrm::dlrm_1_2t().build(48).is_err());
        assert!(Dlrm::dlrm_1_2t().build(64).is_ok());
    }

    #[test]
    fn footprint_halves_with_node_doubling() {
        let d = Dlrm::dlrm_1_2t();
        let f64n = d.footprint_per_node(64);
        let f32n = d.footprint_per_node(32);
        assert!((f32n / f64n - 2.0).abs() < 0.01);
        // 64 nodes: 1.2T fp16 / 64 = 37.5 GB/node (fits 80 GB local).
        assert!((f64n - 37.5e9).abs() < 1e9, "{f64n:.3e}");
    }

    #[test]
    fn alltoall_bytes_shrink_with_more_nodes(){
        let d = Dlrm::dlrm_1_2t();
        let bytes = |n: usize| {
            d.build(n).unwrap().layers[0].comm_fp.bytes
        };
        assert!((bytes(32) / bytes(64) - 2.0).abs() < 1e-9);
        // Pooled exchange: pooling factor must NOT appear in a2a bytes.
        assert_eq!(
            bytes(64),
            d.global_batch * d.tables / 64.0 * d.emb_dim * 2.0
        );
    }

    #[test]
    fn lookup_rows_scale_inverse_nodes() {
        let d = Dlrm::dlrm_1_2t();
        let w = d.build(64).unwrap();
        match w.layers[0].op {
            LayerOp::Lookup { rows, .. } => {
                assert_eq!(
                    rows,
                    d.global_batch * d.pooling * d.tables / 64.0
                );
            }
            _ => panic!("first layer must be the lookup"),
        }
    }

    #[test]
    fn mlp_layers_have_wg_allreduce() {
        let w = Dlrm::dlrm_1_2t().build(64).unwrap();
        let mlp = w
            .layers
            .iter()
            .find(|l| l.name.starts_with("top-mlp"))
            .unwrap();
        assert!(mlp.comm_wg.bytes > 0.0);
        assert_eq!(mlp.comm_wg.scope, CommScope::All);
    }

    #[test]
    fn weight_update_present_and_bandwidth_bound() {
        let w = Dlrm::dlrm_1_2t().build(64).unwrap();
        let wu = w.layers.last().unwrap();
        let q = wu.op.quantities(Phase::Wg);
        assert!(q.w > 0.0);
        assert_eq!(wu.op.quantities(Phase::Fp).w, 0.0);
    }

    #[test]
    fn slots_fit_abi() {
        let w = Dlrm::dlrm_1_2t().build(64).unwrap();
        assert!(w.n_slots() <= 192);
    }
}
