//! Transformer workload builder (paper Table II, modeled after Megatron-LM's
//! hybrid model & data parallelism).
//!
//! MP shards attention heads, the MLP hidden dimension (`sub_ff`), and the
//! vocabulary (`sub_vocab`) across the MP group; DP replicates the sharded
//! model. Table II's `b` (mini-batch size) is a fixed per-replica
//! hyper-parameter: each DP replica processes `b` sequences per iteration
//! regardless of the (MP, DP) split. This is the reading consistent with
//! the paper's Fig. 8 trends — both the compute delay AND the exposed
//! FP/IG communication reach their minimum at MP8_DP128:
//!
//! * high MP → an MP group straddles pods, so the blocking per-stack
//!   all-reduces ride the slow inter-pod links (Table I's logical-ring
//!   collectives) → communication-bound left flank;
//! * low MP → each node holds a `1/MP` model shard and computes `b`
//!   sequences over it, so per-node FLOPs AND weight/optimizer memory
//!   traffic double with every MP halving → memory-bound right flank.
//!
//! WG gradient synchronization follows ZeRO-2: gradients are partitioned
//! across DP, so the per-iteration DP collective is a reduce-scatter of
//! the gradient shard (the fp16 parameter all-gather overlaps with the
//! next iteration's forward pass and is excluded, as in the paper where
//! "WG communication is fully overlapped" everywhere).
//!
//! Layer table mirrors the paper's Table II; per-stack layers are emitted
//! once with `repeat = #stacks`.

use super::gemm::gemm;
use super::layer::{
    Collective, Comm, CommScope, Layer, LayerOp, Workload, FP16,
};
use crate::error::{Error, Result};
use crate::parallel::Strategy;

/// Transformer hyper-parameters (the model "signature" of SIV-A).
#[derive(Debug, Clone, PartialEq)]
pub struct Transformer {
    /// Model name used in reports.
    pub name: String,
    /// Encoder/decoder stack count (Table II's `#Stacks` = N).
    pub stacks: usize,
    /// Hidden dimension `d_model`.
    pub d_model: f64,
    /// Attention heads `h`.
    pub heads: f64,
    /// Sequence length `seq`.
    pub seq: f64,
    /// Vocabulary size.
    pub vocab: f64,
    /// MLP expansion factor (ff = ff_mult x d_model).
    pub ff_mult: f64,
    /// Mini-batch size `b` per model replica, in sequences (Table II).
    pub batch: f64,
}

impl Transformer {
    /// Transformer-1T (Megatron-LM 1T row: 128 stacks, d_model 25600,
    /// 160 heads, seq 2048, vocab 51200). ~1.01e12 parameters.
    pub fn t1() -> Transformer {
        Transformer {
            name: "transformer-1t".into(),
            stacks: 128,
            d_model: 25_600.0,
            heads: 160.0,
            seq: 2048.0,
            vocab: 51_200.0,
            ff_mult: 4.0,
            batch: 16.0,
        }
    }

    /// A ~100M-parameter configuration (GPT-2-small-ish) used by the
    /// end-to-end examples and tests where full 1T scale is unnecessary.
    pub fn t100m() -> Transformer {
        Transformer {
            name: "transformer-100m".into(),
            stacks: 12,
            d_model: 768.0,
            heads: 12.0,
            seq: 1024.0,
            vocab: 50_304.0,
            ff_mult: 4.0,
            batch: 8.0,
        }
    }

    /// Total parameter count (the `12 L d^2` transformer rule plus
    /// embeddings).
    pub fn total_params(&self) -> f64 {
        let d = self.d_model;
        let per_stack = (4.0 + 2.0 * self.ff_mult) * d * d; // QKV+proj + MLP
        self.stacks as f64 * per_stack + 2.0 * self.vocab * d
    }

    /// Key/value width per head.
    pub fn d_head(&self) -> f64 {
        self.d_model / self.heads
    }

    /// Decompose into per-node layers for a parallelization strategy.
    ///
    /// Errors if MP exceeds the head count (cannot shard further) or PP
    /// exceeds the stack count (cannot pipeline deeper than the layer
    /// stacks). With `pp > 1` the returned workload still carries the
    /// full MP-shard layer list; the contiguous stage split happens at
    /// derivation time via [`Workload::stage_partition`].
    pub fn build(&self, strategy: &Strategy) -> Result<Workload> {
        let mp = strategy.mp as f64;
        let dp = strategy.dp as f64;
        if mp > self.heads {
            return Err(Error::Config(format!(
                "MP {} > heads {}: cannot shard attention",
                strategy.mp, self.heads
            )));
        }
        if strategy.pp > self.stacks {
            return Err(Error::Config(format!(
                "PP {} > stacks {}: cannot pipeline deeper than the stack \
                 count",
                strategy.pp, self.stacks
            )));
        }
        let d = self.d_model;
        let seq = self.seq;
        let b = self.batch; // per-replica mini-batch (Table II's `b`)
        let rows = b * seq; // GEMM M dimension
        let ff = self.ff_mult * d;
        let sub_d = d / mp; // sharded head block (h/mp x d_k)
        let sub_ff = ff / mp;
        let sub_vocab = self.vocab / mp;
        let n_stacks = self.stacks as f64;

        // The two Megatron blocking all-reduces per stack (attention output
        // and MLP output), in both FP and IG, across the MP group.
        let ar_mp = Comm::allreduce(rows * d * FP16, CommScope::Mp);

        // WG data-parallel gradient reduce-scatter, per GEMM layer, of that
        // layer's weight-shard bytes (ZeRO-2: gradients partitioned across
        // DP — SIV-B; the parameter all-gather overlaps the next forward).
        let wg_ar = |k: f64, n: f64| Comm {
            collective: Collective::ReduceScatter,
            bytes: k * n * FP16,
            scope: CommScope::Dp,
        };

        let mut layers = Vec::new();

        // --- embeddings (once) --------------------------------------------
        let mut input_emb = Layer::new(
            "input-embedding",
            LayerOp::Lookup {
                rows,
                width: d,
            },
            1.0,
        );
        input_emb.extra_params = sub_vocab * d;
        // Vocab-parallel embedding: all-reduce the gathered activations.
        input_emb.comm_fp = ar_mp;
        input_emb.comm_wg = Comm {
            collective: Collective::ReduceScatter,
            bytes: sub_vocab * d * FP16,
            scope: CommScope::Dp,
        };
        layers.push(input_emb);

        // --- per-stack layers (repeat = stacks) ----------------------------
        let ew = |name: &str, ops: f64| {
            Layer::new(
                name,
                LayerOp::Elementwise {
                    elems: rows * d,
                    ops,
                },
                n_stacks,
            )
        };
        layers.push(ew("layernorm-1", 5.0));

        for nm in ["q-proj", "k-proj", "v-proj"] {
            let mut l = Layer::new(nm, gemm(rows, d, sub_d), n_stacks);
            l.comm_wg = wg_ar(d, sub_d);
            layers.push(l);
        }

        // Attention scores U = softmax(QK^T/sqrt(d_k)) and Y = UV. Table II
        // writes these as (b.seq x h.d_k) x (h.d_k x b.seq) GEMMs; we keep
        // the N dimension per-sample (seq, not b.seq) so FLOPs scale
        // linearly with the microbatch, matching real block-diagonal
        // attention rather than cross-batch mixing.
        layers.push(Layer::new(
            "attn-scores",
            gemm(rows, sub_d, seq),
            n_stacks,
        ));
        layers.push(Layer::new("attn-values", gemm(rows, seq, sub_d), n_stacks));

        // Output projection (row-parallel): blocking MP all-reduce in FP
        // and IG.
        let mut zproj = Layer::new("attn-out-proj", gemm(rows, sub_d, d), n_stacks);
        zproj.comm_fp = ar_mp;
        zproj.comm_ig = ar_mp;
        zproj.comm_wg = wg_ar(sub_d, d);
        layers.push(zproj);

        layers.push(ew("residual-1", 1.0));
        layers.push(ew("layernorm-2", 5.0));

        let mut mlp1 = Layer::new("mlp-1", gemm(rows, d, sub_ff), n_stacks);
        mlp1.comm_wg = wg_ar(d, sub_ff);
        layers.push(mlp1);

        let mut mlp2 = Layer::new("mlp-2", gemm(rows, sub_ff, d), n_stacks);
        mlp2.comm_fp = ar_mp;
        mlp2.comm_ig = ar_mp;
        mlp2.comm_wg = wg_ar(sub_ff, d);
        layers.push(mlp2);

        layers.push(ew("residual-2", 1.0));

        // --- output embedding / LM head (once) -----------------------------
        let mut out_emb = Layer::new(
            "output-embedding",
            gemm(rows, d, sub_vocab),
            1.0,
        );
        // Vocab-parallel softmax reduction (small) in FP; activation-grad
        // all-reduce in IG.
        out_emb.comm_fp = Comm::allreduce(rows * FP16, CommScope::Mp);
        out_emb.comm_ig = ar_mp;
        out_emb.comm_wg = wg_ar(d, sub_vocab);
        layers.push(out_emb);

        // --- optimizer weight update (once, covers every shard) ------------
        // Mixed-precision Adam streams every model state of the node's MP
        // shard through memory once in and once out: fp16 params (2 B) +
        // fp16 grads (2 B) + fp32 master/momentum/variance (12 B), read +
        // write = 32 B/param. This 1/MP traffic term is what makes low-MP
        // configurations memory-(bandwidth-)bound — Fig. 8's right flank.
        let shard_params = self.total_params() / mp;
        let update_bytes = shard_params * 2.0 * (2.0 + 2.0 + 12.0);
        let _ = dp;
        layers.push(Layer::new(
            "weight-update",
            LayerOp::WeightUpdate {
                params: shard_params,
                bytes: update_bytes,
            },
            1.0,
        ));

        Ok(Workload {
            name: format!("{}@{}", self.name, strategy.label()),
            layers,
            mp: strategy.mp,
            dp: strategy.dp,
            pp: strategy.pp,
            nodes: strategy.nodes(),
            total_params: self.total_params(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t1_is_one_trillion() {
        let t = Transformer::t1();
        let p = t.total_params();
        assert!(
            (0.95e12..1.1e12).contains(&p),
            "Transformer-1T params {p:.3e}"
        );
    }

    #[test]
    fn t100m_is_about_100m() {
        let p = Transformer::t100m().total_params();
        assert!((0.8e8..2.0e8).contains(&p), "params {p:.3e}");
    }

    #[test]
    fn build_rejects_mp_beyond_heads() {
        let t = Transformer::t1();
        assert!(t.build(&Strategy::new(256, 4).unwrap()).is_err());
        assert!(t.build(&Strategy::new(128, 8).unwrap()).is_ok());
    }

    #[test]
    fn build_carries_pipeline_degree() {
        let t = Transformer::t1();
        let s = Strategy::new_3d(8, 16, 8).unwrap();
        let w = t.build(&s).unwrap();
        assert_eq!(w.pp, 8);
        assert_eq!(w.nodes, 1024);
        assert_eq!(w.name, "transformer-1t@MP8_DP16_PP8");
        // The layer list is the full MP shard regardless of PP.
        let flat = t.build(&Strategy::new(8, 128).unwrap()).unwrap();
        assert_eq!(w.layers, flat.layers);
        // PP beyond the stack count cannot be pipelined.
        assert!(t.build(&Strategy::new_3d(8, 1, 256).unwrap()).is_err());
    }

    #[test]
    fn params_per_node_scale_with_mp() {
        let t = Transformer::t1();
        let w8 = t.build(&Strategy::new(8, 128).unwrap()).unwrap();
        let w16 = t.build(&Strategy::new(16, 64).unwrap()).unwrap();
        let r = w8.params_per_node() / w16.params_per_node();
        assert!((r - 2.0).abs() < 0.05, "ratio {r}");
    }

    #[test]
    fn per_node_flops_double_when_mp_halves() {
        // Fixed per-replica batch: each node computes b sequences over a
        // 1/MP model shard, so halving MP doubles per-node FLOPs.
        let t = Transformer::t1();
        let f8 = t.build(&Strategy::new(8, 128).unwrap()).unwrap().total_flops();
        let f16 = t.build(&Strategy::new(16, 64).unwrap()).unwrap().total_flops();
        let r = f16 / f8;
        assert!((r - 0.5).abs() < 0.05, "ratio {r}");
    }

    #[test]
    fn mp_allreduce_bytes_constant_across_strategies() {
        // Table II's b is per-replica, so the blocking MP all-reduce
        // payload (b x seq x d_model) is strategy-independent.
        let t = Transformer::t1();
        let ar = |mp: usize, dp: usize| {
            t.build(&Strategy::new(mp, dp).unwrap())
                .unwrap()
                .layers
                .iter()
                .find(|l| l.name == "mlp-2")
                .unwrap()
                .comm_fp
                .bytes
        };
        assert_eq!(ar(8, 128), ar(64, 16));
        assert_eq!(ar(8, 128), 16.0 * 2048.0 * 25_600.0 * 2.0);
    }

    #[test]
    fn wg_sync_is_reduce_scatter() {
        let t = Transformer::t1();
        let w = t.build(&Strategy::new(8, 128).unwrap()).unwrap();
        let mlp = w.layers.iter().find(|l| l.name == "mlp-1").unwrap();
        assert_eq!(mlp.comm_wg.collective, Collective::ReduceScatter);
        assert_eq!(mlp.comm_wg.scope, CommScope::Dp);
    }

    #[test]
    fn layer_count_fits_abi() {
        let w = Transformer::t1().build(&Strategy::new(8, 128).unwrap()).unwrap();
        assert!(w.n_slots() <= 192, "slots {}", w.n_slots());
        assert!(w.n_slots() >= 10);
    }

    #[test]
    fn weight_update_traffic_grows_as_mp_shrinks() {
        let t = Transformer::t1();
        let wu_bytes = |mp: usize, dp: usize| {
            let w = t.build(&Strategy::new(mp, dp).unwrap()).unwrap();
            let l = w
                .layers
                .iter()
                .find(|l| l.name == "weight-update")
                .unwrap();
            l.op.quantities(crate::workload::Phase::Wg).w
        };
        assert!(wu_bytes(8, 128) > 3.0 * wu_bytes(64, 16));
    }
}
