//! GEMM helpers shared by the workload builders.

use super::layer::{LayerOp, Phase};

/// FLOPs of one `(m x k) . (k x n)` GEMM (multiply-accumulate = 2 ops).
pub fn gemm_flops(m: f64, k: f64, n: f64) -> f64 {
    2.0 * m * k * n
}

/// Total FLOPs for one training iteration of a GEMM layer (FP + IG + WG,
/// the standard 3x forward cost).
pub fn training_flops(m: f64, k: f64, n: f64) -> f64 {
    3.0 * gemm_flops(m, k, n)
}

/// Build a GEMM op, asserting positive dimensions in debug builds.
pub fn gemm(m: f64, k: f64, n: f64) -> LayerOp {
    debug_assert!(m > 0.0 && k > 0.0 && n > 0.0, "bad GEMM dims {m}x{k}x{n}");
    LayerOp::Gemm { m, k, n }
}

/// Weight bytes of a GEMM layer in fp16.
pub fn weight_bytes(k: f64, n: f64) -> f64 {
    k * n * super::layer::FP16
}

/// Sanity relation used by property tests: per-phase quantities of a GEMM
/// conserve total element counts across phases.
pub fn phase_operand_elems(op: &LayerOp, phase: Phase) -> f64 {
    let q = op.quantities(phase);
    (q.u + q.v + q.w) / super::layer::FP16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_formula() {
        assert_eq!(gemm_flops(2.0, 3.0, 4.0), 48.0);
        assert_eq!(training_flops(2.0, 3.0, 4.0), 144.0);
    }

    #[test]
    fn operand_elems_identical_across_phases() {
        // Each phase touches the same three matrices (m.k + k.n + m.n).
        let op = gemm(6.0, 7.0, 8.0);
        let fp = phase_operand_elems(&op, Phase::Fp);
        let ig = phase_operand_elems(&op, Phase::Ig);
        let wg = phase_operand_elems(&op, Phase::Wg);
        assert_eq!(fp, ig);
        assert_eq!(fp, wg);
        assert_eq!(fp, 6.0 * 7.0 + 7.0 * 8.0 + 6.0 * 8.0);
    }

    #[test]
    fn weight_bytes_fp16() {
        assert_eq!(weight_bytes(10.0, 20.0), 400.0);
    }
}
