//! GEMM helpers shared by the workload builders, plus [`DenseGemm`] — a
//! single-GEMM microbenchmark workload for the scenario engine.

use super::layer::{
    Collective, Comm, CommScope, Layer, LayerOp, Phase, Workload, FP16,
};
use crate::error::{Error, Result};
use crate::parallel::Strategy;

/// FLOPs of one `(m x k) . (k x n)` GEMM (multiply-accumulate = 2 ops).
pub fn gemm_flops(m: f64, k: f64, n: f64) -> f64 {
    2.0 * m * k * n
}

/// Total FLOPs for one training iteration of a GEMM layer (FP + IG + WG,
/// the standard 3x forward cost).
pub fn training_flops(m: f64, k: f64, n: f64) -> f64 {
    3.0 * gemm_flops(m, k, n)
}

/// Build a GEMM op, asserting positive dimensions in debug builds.
pub fn gemm(m: f64, k: f64, n: f64) -> LayerOp {
    debug_assert!(m > 0.0 && k > 0.0 && n > 0.0, "bad GEMM dims {m}x{k}x{n}");
    LayerOp::Gemm { m, k, n }
}

/// Weight bytes of a GEMM layer in fp16.
pub fn weight_bytes(k: f64, n: f64) -> f64 {
    k * n * super::layer::FP16
}

/// Sanity relation used by property tests: per-phase quantities of a GEMM
/// conserve total element counts across phases.
pub fn phase_operand_elems(op: &LayerOp, phase: Phase) -> f64 {
    let q = op.quantities(phase);
    (q.u + q.v + q.w) / super::layer::FP16
}

/// A single dense GEMM treated as a trainable "model": `Y = X(m x k) .
/// W(k x n)` plus the mixed-precision Adam update of its `k x n` weights.
///
/// This is the scenario engine's microbenchmark workload — it isolates the
/// roofline + collective cost model on one layer, which makes bandwidth
/// and strategy sensitivities directly legible. Data parallelism splits
/// the `m` (batch) dimension and all-reduces the full weight gradient;
/// model parallelism is intentionally unsupported (a lone GEMM has no
/// Megatron-style shard structure worth modeling).
#[derive(Debug, Clone, PartialEq)]
pub struct DenseGemm {
    /// Workload name used in reports (default "gemm").
    pub name: String,
    /// Batch (rows) dimension of the activation operand.
    pub m: f64,
    /// Contraction dimension.
    pub k: f64,
    /// Output-feature dimension (the weight is `k x n`).
    pub n: f64,
}

impl DenseGemm {
    /// A GEMM workload with the default name.
    pub fn new(m: f64, k: f64, n: f64) -> DenseGemm {
        DenseGemm {
            name: "gemm".into(),
            m,
            k,
            n,
        }
    }

    /// Weight parameters (`k x n`).
    pub fn total_params(&self) -> f64 {
        self.k * self.n
    }

    /// Decompose for a strategy. Only data parallelism is supported:
    /// `mp` and `pp` must be 1, and `dp` splits the batch dimension.
    pub fn build(&self, strategy: &Strategy) -> Result<Workload> {
        if strategy.mp != 1 {
            return Err(Error::Config(format!(
                "GEMM workload supports data parallelism only (MP must be \
                 1, got {})",
                strategy.mp
            )));
        }
        if strategy.pp != 1 {
            return Err(Error::Config(format!(
                "GEMM workload supports data parallelism only (PP must be \
                 1, got {}): a single layer has no pipeline stages",
                strategy.pp
            )));
        }
        let dp = strategy.dp as f64;
        let rows = self.m / dp;
        if rows < 1.0 || self.k < 1.0 || self.n < 1.0 {
            return Err(Error::Config(format!(
                "GEMM {}x{}x{} cannot be split {} ways",
                self.m, self.k, self.n, strategy.dp
            )));
        }
        let mut mm = Layer::new("gemm", gemm(rows, self.k, self.n), 1.0);
        mm.comm_wg = Comm {
            collective: Collective::AllReduce,
            bytes: self.k * self.n * FP16,
            scope: CommScope::Dp,
        };
        let params = self.total_params();
        // Mixed-precision Adam streams 16 B of state per param, read +
        // write (same accounting as the Transformer builder).
        let update = Layer::new(
            "weight-update",
            LayerOp::WeightUpdate {
                params,
                bytes: params * 32.0,
            },
            1.0,
        );
        Ok(Workload {
            name: format!("{}@{}", self.name, strategy.label()),
            layers: vec![mm, update],
            mp: 1,
            dp: strategy.dp,
            pp: 1,
            nodes: strategy.dp,
            total_params: params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_formula() {
        assert_eq!(gemm_flops(2.0, 3.0, 4.0), 48.0);
        assert_eq!(training_flops(2.0, 3.0, 4.0), 144.0);
    }

    #[test]
    fn operand_elems_identical_across_phases() {
        // Each phase touches the same three matrices (m.k + k.n + m.n).
        let op = gemm(6.0, 7.0, 8.0);
        let fp = phase_operand_elems(&op, Phase::Fp);
        let ig = phase_operand_elems(&op, Phase::Ig);
        let wg = phase_operand_elems(&op, Phase::Wg);
        assert_eq!(fp, ig);
        assert_eq!(fp, wg);
        assert_eq!(fp, 6.0 * 7.0 + 7.0 * 8.0 + 6.0 * 8.0);
    }

    #[test]
    fn weight_bytes_fp16() {
        assert_eq!(weight_bytes(10.0, 20.0), 400.0);
    }

    #[test]
    fn dense_gemm_builds_dp_workload() {
        let g = DenseGemm::new(65_536.0, 8192.0, 8192.0);
        let w = g.build(&Strategy::new(1, 8).unwrap()).unwrap();
        assert_eq!(w.nodes, 8);
        assert_eq!(w.layers.len(), 2);
        // Batch split 8 ways; weight shard replicated.
        match w.layers[0].op {
            LayerOp::Gemm { m, k, n } => {
                assert_eq!(m, 65_536.0 / 8.0);
                assert_eq!((k, n), (8192.0, 8192.0));
            }
            _ => panic!("first layer must be the GEMM"),
        }
        assert_eq!(w.layers[0].comm_wg.collective, Collective::AllReduce);
        assert_eq!(w.layers[0].comm_wg.bytes, 8192.0 * 8192.0 * FP16);
        assert_eq!(w.total_params, 8192.0 * 8192.0);
    }

    #[test]
    fn dense_gemm_rejects_mp_and_oversplit() {
        let g = DenseGemm::new(64.0, 64.0, 64.0);
        assert!(g.build(&Strategy::new(2, 4).unwrap()).is_err());
        assert!(g.build(&Strategy::new(1, 128).unwrap()).is_err());
        assert!(g.build(&Strategy::new(1, 64).unwrap()).is_ok());
        assert!(g.build(&Strategy::new_3d(1, 8, 2).unwrap()).is_err());
    }
}
