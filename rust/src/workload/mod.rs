//! Workload frontend (paper SIII-A / SIV-A): decompose a DL model into
//! layers, each a GEMM (or lookup / element-wise op) with explicit FLOP,
//! byte, and collective-communication counts for the three training phases.

pub mod dlrm;
pub mod gemm;
pub mod layer;
pub mod trace;
pub mod transformer;

pub use layer::{
    Collective, Comm, CommScope, Layer, LayerOp, Phase, PhaseQuantities,
    StageSlice, Workload, FP16,
};
