//! Chunked collective schedules for the discrete-event backend.
//!
//! The DES does not integrate closed-form costs; it *executes* collectives
//! as sequences of link-level transfer phases (as ASTRA-SIM's system layer
//! schedules chunks onto the network layer). Each [`TransferPhase`] is a
//! synchronous ring step: every participant simultaneously sends `bytes`
//! over one link class, taking `bytes / bw + lat`.

use super::collectives::{CollectiveImpl, CollectiveSpec};
use crate::workload::Collective;

/// Which link class a phase occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// Intra-pod links (NVLink-class).
    IntraPod,
    /// Inter-pod links (fabric-class).
    InterPod,
}

/// One synchronous transfer step of a collective schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferPhase {
    /// Link class this step serializes on.
    pub link: LinkClass,
    /// Bytes each participant moves in this step.
    pub bytes: f64,
    /// Ring steps folded into this phase (latency hops).
    pub hops: usize,
}

/// Expand a collective into its transfer phases.
///
/// Allocating wrapper around [`schedule_into`]; the DES hot loop reuses a
/// scratch buffer instead.
pub fn schedule(spec: &CollectiveSpec, impl_: CollectiveImpl) -> Vec<TransferPhase> {
    let mut phases = Vec::new();
    schedule_into(spec, impl_, &mut phases);
    phases
}

/// Expand a collective into its transfer phases, writing into `phases`
/// (cleared first) so per-evaluation allocations can be reused.
///
/// Logical ring: one flat ring pass (two for all-reduce) over all n
/// participants, on the slowest link class the ring crosses. Hierarchical:
/// intra reduce-scatter, inter reduce-scatter + all-gather on the
/// `bytes/n_intra` shard, intra all-gather. All-to-all: one concurrent
/// phase per link class (the DES serializes them on their own links,
/// reproducing the analytical max()).
pub fn schedule_into(
    spec: &CollectiveSpec,
    impl_: CollectiveImpl,
    phases: &mut Vec<TransferPhase>,
) {
    phases.clear();
    let n = spec.n();
    if spec.bytes <= 0.0 || n <= 1 {
        return;
    }
    let ni = spec.n_intra;
    let nx = spec.n_inter;
    let shard = spec.bytes / ni.max(1) as f64;

    let flat_link = if nx > 1 {
        LinkClass::InterPod
    } else {
        LinkClass::IntraPod
    };
    let flat_pass = |phases: &mut Vec<TransferPhase>| {
        phases.push(TransferPhase {
            link: flat_link,
            bytes: spec.bytes * (n as f64 - 1.0) / n as f64,
            hops: n - 1,
        });
    };
    let intra_pass = |phases: &mut Vec<TransferPhase>, bytes: f64| {
        if ni > 1 {
            phases.push(TransferPhase {
                link: LinkClass::IntraPod,
                bytes: bytes * (ni as f64 - 1.0) / ni as f64,
                hops: ni - 1,
            });
        }
    };
    let inter_pass = |phases: &mut Vec<TransferPhase>, bytes: f64| {
        if nx > 1 {
            phases.push(TransferPhase {
                link: LinkClass::InterPod,
                bytes: bytes * (nx as f64 - 1.0) / nx as f64,
                hops: nx - 1,
            });
        }
    };

    match (spec.collective, impl_) {
        (Collective::None, _) => {}
        (Collective::AllReduce, CollectiveImpl::LogicalRing) => {
            flat_pass(&mut phases);
            flat_pass(&mut phases);
        }
        (Collective::AllReduce, CollectiveImpl::Hierarchical) => {
            intra_pass(&mut phases, spec.bytes); // reduce-scatter
            inter_pass(&mut phases, shard); // inter RS
            inter_pass(&mut phases, shard); // inter AG
            intra_pass(&mut phases, spec.bytes); // all-gather
        }
        (
            Collective::AllGather | Collective::ReduceScatter,
            CollectiveImpl::LogicalRing,
        ) => {
            flat_pass(&mut phases);
        }
        (
            Collective::AllGather | Collective::ReduceScatter,
            CollectiveImpl::Hierarchical,
        ) => {
            intra_pass(&mut phases, spec.bytes);
            inter_pass(&mut phases, shard);
        }
        (Collective::AllToAll, _) => {
            let peers = (n as f64 - 1.0).max(1.0);
            let f_intra = (ni as f64 - 1.0).max(0.0) / peers;
            if f_intra > 0.0 {
                phases.push(TransferPhase {
                    link: LinkClass::IntraPod,
                    bytes: spec.bytes * f_intra,
                    hops: ni - 1,
                });
            }
            if f_intra < 1.0 {
                phases.push(TransferPhase {
                    link: LinkClass::InterPod,
                    bytes: spec.bytes * (1.0 - f_intra),
                    hops: n - ni.max(1),
                });
            }
        }
    }
}

/// Whether the phases of this collective may proceed concurrently on their
/// link classes (true only for all-to-all).
pub fn concurrent_phases(c: Collective) -> bool {
    matches!(c, Collective::AllToAll)
}

/// One synchronous transfer step addressed by link-class *index*: the
/// topology tier (innermost first) for tier-annotated specs, or
/// `{0 = intra-pod, 1 = inter-pod}` for legacy two-level specs. This is
/// the engine's native phase type — tiered collectives run on their
/// N-tier FIFO links directly instead of projecting onto two classes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierPhase {
    /// Link-class index this step serializes on.
    pub tier: usize,
    /// Bytes each participant moves in this step.
    pub bytes: f64,
    /// Ring steps folded into this phase (latency hops).
    pub hops: usize,
}

/// Class index of a legacy two-level link class.
pub fn class_of(link: LinkClass) -> usize {
    match link {
        LinkClass::IntraPod => 0,
        LinkClass::InterPod => 1,
    }
}

/// Expand a tier-annotated collective into per-tier transfer phases,
/// writing into `phases` (cleared first) — the k-tier generalization of
/// [`schedule_into`], mirroring `collective_cost_tiered` pass for pass:
/// hierarchical impls ring up the chain on the progressively reduced
/// shard and back down; logical-ring impls serialize one flat ring at
/// the outermost tier the group crosses; all-to-all emits one
/// concurrent phase per tier carrying the fraction of peers first
/// reachable there. Serially integrating the schedule on idle links
/// reproduces the closed form (exactly for ring passes; all-to-all
/// differs in how per-phase latency accrues, same as the legacy
/// two-level schedule).
pub fn schedule_tiered_into(
    spec: &CollectiveSpec,
    impl_: CollectiveImpl,
    phases: &mut Vec<TierPhase>,
) {
    phases.clear();
    let k = spec.n_tiers.clamp(1, crate::config::MAX_TIERS);
    let n_us: usize = spec.tier_n[..k].iter().product();
    let n = n_us as f64;
    if spec.bytes <= 0.0 || n_us <= 1 {
        return;
    }
    // Shard entering each tier (payload reduced by all tiers below),
    // same recurrence as the closed form.
    let mut shard = [0.0_f64; crate::config::MAX_TIERS];
    let mut b = spec.bytes;
    for t in 0..k {
        shard[t] = b;
        b /= (spec.tier_n[t] as f64).max(1.0);
    }
    let cross = (0..k).rev().find(|&t| spec.tier_n[t] > 1).unwrap_or(0);
    // One ring pass (RS or AG) over tier t's group on its own links;
    // `(n-1)/n * bytes` matches ring_pass's association bit-for-bit.
    let ring = |phases: &mut Vec<TierPhase>, t: usize, bytes: f64| {
        let nt = spec.tier_n[t];
        if nt > 1 {
            phases.push(TierPhase {
                tier: t,
                bytes: (nt as f64 - 1.0) / nt as f64 * bytes,
                hops: nt - 1,
            });
        }
    };
    let flat = |phases: &mut Vec<TierPhase>| {
        phases.push(TierPhase {
            tier: cross,
            bytes: (n - 1.0) / n * spec.bytes,
            hops: n_us - 1,
        });
    };
    match (spec.collective, impl_) {
        (Collective::None, _) => {}
        (Collective::AllReduce, CollectiveImpl::LogicalRing) => {
            flat(phases);
            flat(phases);
        }
        (Collective::AllReduce, CollectiveImpl::Hierarchical) => {
            for t in 0..k - 1 {
                ring(phases, t, shard[t]); // RS up the chain
            }
            ring(phases, k - 1, shard[k - 1]); // top-tier RS
            ring(phases, k - 1, shard[k - 1]); // top-tier AG
            for t in (0..k - 1).rev() {
                ring(phases, t, shard[t]); // AG back down
            }
        }
        (
            Collective::AllGather | Collective::ReduceScatter,
            CollectiveImpl::LogicalRing,
        ) => {
            flat(phases);
        }
        (
            Collective::AllGather | Collective::ReduceScatter,
            CollectiveImpl::Hierarchical,
        ) => {
            for t in 0..k {
                ring(phases, t, shard[t]);
            }
        }
        (Collective::AllToAll, _) => {
            // Fraction of peers first reachable at each tier (remainder
            // on the last tier), concurrent on their own links — the
            // same split as the closed form's max().
            let peers = (n - 1.0).max(1.0);
            let mut within = 1.0_f64;
            let mut within_us = 1_usize;
            let mut below_last = 0.0;
            for t in 0..k {
                let prev = within;
                let prev_us = within_us;
                within *= spec.tier_n[t] as f64;
                within_us *= spec.tier_n[t];
                let f = if t == k - 1 {
                    1.0 - below_last
                } else if t == 0 {
                    (within - 1.0).max(0.0) / peers
                } else {
                    (within - prev).max(0.0) / peers
                };
                below_last += f;
                let hops = if t == 0 {
                    within_us - 1
                } else {
                    within_us - prev_us
                };
                if f > 0.0 {
                    phases.push(TierPhase {
                        tier: t,
                        bytes: spec.bytes * f,
                        hops,
                    });
                }
            }
        }
    }
}

/// Expand any collective into class-indexed phases: tier-annotated
/// specs go through [`schedule_tiered_into`] natively; legacy two-level
/// specs go through [`schedule_into`] (via `legacy`, a reusable scratch
/// buffer) and map `{IntraPod, InterPod}` onto classes `{0, 1}` — so
/// the legacy phase list is byte-for-byte the old schedule, just
/// re-addressed.
pub fn schedule_classes_into(
    spec: &CollectiveSpec,
    impl_: CollectiveImpl,
    out: &mut Vec<TierPhase>,
    legacy: &mut Vec<TransferPhase>,
) {
    if spec.n_tiers > 0 {
        schedule_tiered_into(spec, impl_, out);
    } else {
        schedule_into(spec, impl_, legacy);
        out.clear();
        out.extend(legacy.iter().map(|p| TierPhase {
            tier: class_of(p.link),
            bytes: p.bytes,
            hops: p.hops,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::collectives::collective_cost;
    use CollectiveImpl::{Hierarchical, LogicalRing};

    fn spec(c: Collective, bytes: f64, ni: usize, nx: usize) -> CollectiveSpec {
        CollectiveSpec::two_level(c, bytes, ni, nx)
    }

    /// Integrating the schedule serially (or max() for all-to-all) must
    /// reproduce the closed-form analytical cost exactly.
    fn integrate(
        s: &CollectiveSpec,
        bwi: f64,
        bwx: f64,
        lat: f64,
        impl_: CollectiveImpl,
    ) -> f64 {
        let phases = schedule(s, impl_);
        let t = |p: &TransferPhase| {
            let bw = match p.link {
                LinkClass::IntraPod => bwi,
                LinkClass::InterPod => bwx,
            };
            p.bytes / bw + p.hops as f64 * lat
        };
        if concurrent_phases(s.collective) {
            phases.iter().map(|p| t(p)).fold(0.0, f64::max)
                + if phases.is_empty() { 0.0 } else { 0.0 }
        } else {
            phases.iter().map(|p| t(p)).sum()
        }
    }

    #[test]
    fn allreduce_schedule_matches_closed_form() {
        for impl_ in [LogicalRing, Hierarchical] {
            for (ni, nx) in [(8, 1), (1, 16), (8, 16), (16, 64), (2, 2)] {
                let s = spec(Collective::AllReduce, 1e9, ni, nx);
                let a = collective_cost(&s, 300e9, 31.25e9, 0.0, impl_);
                let b = integrate(&s, 300e9, 31.25e9, 0.0, impl_);
                assert!((a - b).abs() / a.max(1e-30) < 1e-12, "{ni}x{nx}");
            }
        }
    }

    #[test]
    fn allreduce_schedule_matches_with_latency() {
        for impl_ in [LogicalRing, Hierarchical] {
            for (ni, nx) in [(8, 1), (8, 16), (4, 4)] {
                let s = spec(Collective::AllReduce, 1e9, ni, nx);
                let a = collective_cost(&s, 300e9, 31.25e9, 1e-6, impl_);
                let b = integrate(&s, 300e9, 31.25e9, 1e-6, impl_);
                assert!((a - b).abs() < 1e-12, "{ni}x{nx}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn half_collectives_match() {
        for impl_ in [LogicalRing, Hierarchical] {
            for c in [Collective::AllGather, Collective::ReduceScatter] {
                let s = spec(c, 2e9, 8, 16);
                let a = collective_cost(&s, 300e9, 31.25e9, 1e-6, impl_);
                let b = integrate(&s, 300e9, 31.25e9, 1e-6, impl_);
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn alltoall_concurrency_matches_max() {
        let s = spec(Collective::AllToAll, 64e9, 8, 8);
        let a = collective_cost(&s, 300e9, 31.25e9, 0.0, LogicalRing);
        let b = integrate(&s, 300e9, 31.25e9, 0.0, LogicalRing);
        assert!((a - b).abs() / a < 1e-12);
    }

    #[test]
    fn empty_for_degenerate() {
        for impl_ in [LogicalRing, Hierarchical] {
            assert!(
                schedule(&spec(Collective::AllReduce, 1e9, 1, 1), impl_)
                    .is_empty()
            );
            assert!(
                schedule(&spec(Collective::AllReduce, 0.0, 8, 8), impl_)
                    .is_empty()
            );
            assert!(
                schedule(&spec(Collective::None, 1e9, 8, 8), impl_).is_empty()
            );
        }
    }

    #[test]
    fn schedule_into_clears_and_matches() {
        let s1 = spec(Collective::AllReduce, 1e9, 8, 16);
        let s2 = spec(Collective::AllGather, 2e9, 8, 1);
        let mut buf = Vec::new();
        schedule_into(&s1, Hierarchical, &mut buf);
        assert_eq!(buf, schedule(&s1, Hierarchical));
        // Reusing the buffer drops the previous schedule entirely.
        schedule_into(&s2, LogicalRing, &mut buf);
        assert_eq!(buf, schedule(&s2, LogicalRing));
        schedule_into(&spec(Collective::None, 1e9, 8, 8), LogicalRing, &mut buf);
        assert!(buf.is_empty());
    }

    fn integrate_tiered(
        s: &CollectiveSpec,
        bw: &[f64; 4],
        lat: &[f64; 4],
        impl_: CollectiveImpl,
    ) -> f64 {
        let mut phases = Vec::new();
        schedule_tiered_into(s, impl_, &mut phases);
        let t = |p: &TierPhase| {
            p.bytes / bw[p.tier].max(1.0) + p.hops as f64 * lat[p.tier]
        };
        if concurrent_phases(s.collective) {
            phases.iter().map(|p| t(p)).fold(0.0, f64::max)
        } else {
            phases.iter().map(|p| t(p)).sum()
        }
    }

    // Serially integrating the tiered schedule on idle links must
    // reproduce the tiered closed form — the same pin the legacy
    // two-level schedule carries against collective_cost.
    #[test]
    fn tiered_schedule_matches_closed_form() {
        use crate::network::collectives::collective_cost_tiered;
        let bw = [300e9, 50e9, 12.5e9, 1e9];
        let lat = [1e-7, 5e-7, 1e-6, 2e-6];
        for impl_ in [LogicalRing, Hierarchical] {
            for c in [
                Collective::AllReduce,
                Collective::AllGather,
                Collective::ReduceScatter,
            ] {
                for (tier_n, k) in [
                    ([8usize, 4, 2, 1], 3),
                    ([8, 1, 2, 1], 3),
                    ([2, 2, 2, 2], 4),
                    ([1, 16, 1, 1], 2),
                    ([4, 1, 1, 1], 1),
                ] {
                    let s = CollectiveSpec::tiered(c, 3e9, tier_n, k);
                    let a = collective_cost_tiered(&s, &bw, &lat, impl_);
                    let b = integrate_tiered(&s, &bw, &lat, impl_);
                    assert!(
                        (a - b).abs() <= 1e-12 * a.abs().max(1e-30),
                        "{c:?} {impl_:?} {tier_n:?}x{k}: {a} vs {b}"
                    );
                }
            }
        }
    }

    // All-to-all phases run concurrently per tier; at zero latency the
    // max over phases is the closed form exactly (latency accrues
    // per-phase in the schedule vs once in the closed form — the same
    // accepted divergence as the legacy two-level schedule).
    #[test]
    fn tiered_alltoall_matches_max_at_zero_latency() {
        use crate::network::collectives::collective_cost_tiered;
        let bw = [300e9, 50e9, 12.5e9, 1e9];
        let lat = [0.0; 4];
        for (tier_n, k) in
            [([8usize, 4, 2, 1], 3), ([2, 2, 2, 2], 4), ([8, 8, 1, 1], 2)]
        {
            let s =
                CollectiveSpec::tiered(Collective::AllToAll, 64e9, tier_n, k);
            let a = collective_cost_tiered(&s, &bw, &lat, LogicalRing);
            let b = integrate_tiered(&s, &bw, &lat, LogicalRing);
            assert!(
                (a - b).abs() <= 1e-12 * a.abs(),
                "{tier_n:?}x{k}: {a} vs {b}"
            );
        }
    }

    // The class-indexed expansion of a legacy spec is the legacy
    // schedule verbatim, re-addressed onto classes {0, 1}.
    #[test]
    fn classes_of_legacy_spec_map_schedule_verbatim() {
        let s = spec(Collective::AllReduce, 1e9, 8, 16);
        let mut out = Vec::new();
        let mut scratch = Vec::new();
        schedule_classes_into(&s, Hierarchical, &mut out, &mut scratch);
        let legacy = schedule(&s, Hierarchical);
        assert_eq!(out.len(), legacy.len());
        for (a, b) in out.iter().zip(legacy.iter()) {
            assert_eq!(a.tier, class_of(b.link));
            assert_eq!(a.bytes.to_bits(), b.bytes.to_bits());
            assert_eq!(a.hops, b.hops);
        }
    }

    #[test]
    fn tiered_schedule_degenerate_is_empty() {
        let mut out = Vec::new();
        let s =
            CollectiveSpec::tiered(Collective::AllReduce, 1e9, [1, 1, 1, 1], 3);
        schedule_tiered_into(&s, Hierarchical, &mut out);
        assert!(out.is_empty());
        let s0 =
            CollectiveSpec::tiered(Collective::AllReduce, 0.0, [8, 4, 1, 1], 2);
        schedule_tiered_into(&s0, Hierarchical, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn allreduce_phase_counts() {
        let s = |ni, nx| spec(Collective::AllReduce, 1e9, ni, nx);
        assert_eq!(schedule(&s(8, 16), Hierarchical).len(), 4);
        assert_eq!(schedule(&s(8, 1), Hierarchical).len(), 2);
        assert_eq!(schedule(&s(1, 16), Hierarchical).len(), 2);
        assert_eq!(schedule(&s(8, 16), LogicalRing).len(), 2);
        // Flat ring crossing pods rides the inter-pod links.
        assert_eq!(
            schedule(&s(8, 16), LogicalRing)[0].link,
            LinkClass::InterPod
        );
        assert_eq!(
            schedule(&s(8, 1), LogicalRing)[0].link,
            LinkClass::IntraPod
        );
    }
}
