//! Chunked collective schedules for the discrete-event backend.
//!
//! The DES does not integrate closed-form costs; it *executes* collectives
//! as sequences of link-level transfer phases (as ASTRA-SIM's system layer
//! schedules chunks onto the network layer). Each [`TransferPhase`] is a
//! synchronous ring step: every participant simultaneously sends `bytes`
//! over one link class, taking `bytes / bw + lat`.

use super::collectives::{CollectiveImpl, CollectiveSpec};
use crate::workload::Collective;

/// Which link class a phase occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// Intra-pod links (NVLink-class).
    IntraPod,
    /// Inter-pod links (fabric-class).
    InterPod,
}

/// One synchronous transfer step of a collective schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferPhase {
    /// Link class this step serializes on.
    pub link: LinkClass,
    /// Bytes each participant moves in this step.
    pub bytes: f64,
    /// Ring steps folded into this phase (latency hops).
    pub hops: usize,
}

/// Expand a collective into its transfer phases.
///
/// Allocating wrapper around [`schedule_into`]; the DES hot loop reuses a
/// scratch buffer instead.
pub fn schedule(spec: &CollectiveSpec, impl_: CollectiveImpl) -> Vec<TransferPhase> {
    let mut phases = Vec::new();
    schedule_into(spec, impl_, &mut phases);
    phases
}

/// Expand a collective into its transfer phases, writing into `phases`
/// (cleared first) so per-evaluation allocations can be reused.
///
/// Logical ring: one flat ring pass (two for all-reduce) over all n
/// participants, on the slowest link class the ring crosses. Hierarchical:
/// intra reduce-scatter, inter reduce-scatter + all-gather on the
/// `bytes/n_intra` shard, intra all-gather. All-to-all: one concurrent
/// phase per link class (the DES serializes them on their own links,
/// reproducing the analytical max()).
pub fn schedule_into(
    spec: &CollectiveSpec,
    impl_: CollectiveImpl,
    phases: &mut Vec<TransferPhase>,
) {
    phases.clear();
    let n = spec.n();
    if spec.bytes <= 0.0 || n <= 1 {
        return;
    }
    let ni = spec.n_intra;
    let nx = spec.n_inter;
    let shard = spec.bytes / ni.max(1) as f64;

    let flat_link = if nx > 1 {
        LinkClass::InterPod
    } else {
        LinkClass::IntraPod
    };
    let flat_pass = |phases: &mut Vec<TransferPhase>| {
        phases.push(TransferPhase {
            link: flat_link,
            bytes: spec.bytes * (n as f64 - 1.0) / n as f64,
            hops: n - 1,
        });
    };
    let intra_pass = |phases: &mut Vec<TransferPhase>, bytes: f64| {
        if ni > 1 {
            phases.push(TransferPhase {
                link: LinkClass::IntraPod,
                bytes: bytes * (ni as f64 - 1.0) / ni as f64,
                hops: ni - 1,
            });
        }
    };
    let inter_pass = |phases: &mut Vec<TransferPhase>, bytes: f64| {
        if nx > 1 {
            phases.push(TransferPhase {
                link: LinkClass::InterPod,
                bytes: bytes * (nx as f64 - 1.0) / nx as f64,
                hops: nx - 1,
            });
        }
    };

    match (spec.collective, impl_) {
        (Collective::None, _) => {}
        (Collective::AllReduce, CollectiveImpl::LogicalRing) => {
            flat_pass(&mut phases);
            flat_pass(&mut phases);
        }
        (Collective::AllReduce, CollectiveImpl::Hierarchical) => {
            intra_pass(&mut phases, spec.bytes); // reduce-scatter
            inter_pass(&mut phases, shard); // inter RS
            inter_pass(&mut phases, shard); // inter AG
            intra_pass(&mut phases, spec.bytes); // all-gather
        }
        (
            Collective::AllGather | Collective::ReduceScatter,
            CollectiveImpl::LogicalRing,
        ) => {
            flat_pass(&mut phases);
        }
        (
            Collective::AllGather | Collective::ReduceScatter,
            CollectiveImpl::Hierarchical,
        ) => {
            intra_pass(&mut phases, spec.bytes);
            inter_pass(&mut phases, shard);
        }
        (Collective::AllToAll, _) => {
            let peers = (n as f64 - 1.0).max(1.0);
            let f_intra = (ni as f64 - 1.0).max(0.0) / peers;
            if f_intra > 0.0 {
                phases.push(TransferPhase {
                    link: LinkClass::IntraPod,
                    bytes: spec.bytes * f_intra,
                    hops: ni - 1,
                });
            }
            if f_intra < 1.0 {
                phases.push(TransferPhase {
                    link: LinkClass::InterPod,
                    bytes: spec.bytes * (1.0 - f_intra),
                    hops: n - ni.max(1),
                });
            }
        }
    }
}

/// Whether the phases of this collective may proceed concurrently on their
/// link classes (true only for all-to-all).
pub fn concurrent_phases(c: Collective) -> bool {
    matches!(c, Collective::AllToAll)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::collectives::collective_cost;
    use CollectiveImpl::{Hierarchical, LogicalRing};

    fn spec(c: Collective, bytes: f64, ni: usize, nx: usize) -> CollectiveSpec {
        CollectiveSpec::two_level(c, bytes, ni, nx)
    }

    /// Integrating the schedule serially (or max() for all-to-all) must
    /// reproduce the closed-form analytical cost exactly.
    fn integrate(
        s: &CollectiveSpec,
        bwi: f64,
        bwx: f64,
        lat: f64,
        impl_: CollectiveImpl,
    ) -> f64 {
        let phases = schedule(s, impl_);
        let t = |p: &TransferPhase| {
            let bw = match p.link {
                LinkClass::IntraPod => bwi,
                LinkClass::InterPod => bwx,
            };
            p.bytes / bw + p.hops as f64 * lat
        };
        if concurrent_phases(s.collective) {
            phases.iter().map(|p| t(p)).fold(0.0, f64::max)
                + if phases.is_empty() { 0.0 } else { 0.0 }
        } else {
            phases.iter().map(|p| t(p)).sum()
        }
    }

    #[test]
    fn allreduce_schedule_matches_closed_form() {
        for impl_ in [LogicalRing, Hierarchical] {
            for (ni, nx) in [(8, 1), (1, 16), (8, 16), (16, 64), (2, 2)] {
                let s = spec(Collective::AllReduce, 1e9, ni, nx);
                let a = collective_cost(&s, 300e9, 31.25e9, 0.0, impl_);
                let b = integrate(&s, 300e9, 31.25e9, 0.0, impl_);
                assert!((a - b).abs() / a.max(1e-30) < 1e-12, "{ni}x{nx}");
            }
        }
    }

    #[test]
    fn allreduce_schedule_matches_with_latency() {
        for impl_ in [LogicalRing, Hierarchical] {
            for (ni, nx) in [(8, 1), (8, 16), (4, 4)] {
                let s = spec(Collective::AllReduce, 1e9, ni, nx);
                let a = collective_cost(&s, 300e9, 31.25e9, 1e-6, impl_);
                let b = integrate(&s, 300e9, 31.25e9, 1e-6, impl_);
                assert!((a - b).abs() < 1e-12, "{ni}x{nx}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn half_collectives_match() {
        for impl_ in [LogicalRing, Hierarchical] {
            for c in [Collective::AllGather, Collective::ReduceScatter] {
                let s = spec(c, 2e9, 8, 16);
                let a = collective_cost(&s, 300e9, 31.25e9, 1e-6, impl_);
                let b = integrate(&s, 300e9, 31.25e9, 1e-6, impl_);
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn alltoall_concurrency_matches_max() {
        let s = spec(Collective::AllToAll, 64e9, 8, 8);
        let a = collective_cost(&s, 300e9, 31.25e9, 0.0, LogicalRing);
        let b = integrate(&s, 300e9, 31.25e9, 0.0, LogicalRing);
        assert!((a - b).abs() / a < 1e-12);
    }

    #[test]
    fn empty_for_degenerate() {
        for impl_ in [LogicalRing, Hierarchical] {
            assert!(
                schedule(&spec(Collective::AllReduce, 1e9, 1, 1), impl_)
                    .is_empty()
            );
            assert!(
                schedule(&spec(Collective::AllReduce, 0.0, 8, 8), impl_)
                    .is_empty()
            );
            assert!(
                schedule(&spec(Collective::None, 1e9, 8, 8), impl_).is_empty()
            );
        }
    }

    #[test]
    fn schedule_into_clears_and_matches() {
        let s1 = spec(Collective::AllReduce, 1e9, 8, 16);
        let s2 = spec(Collective::AllGather, 2e9, 8, 1);
        let mut buf = Vec::new();
        schedule_into(&s1, Hierarchical, &mut buf);
        assert_eq!(buf, schedule(&s1, Hierarchical));
        // Reusing the buffer drops the previous schedule entirely.
        schedule_into(&s2, LogicalRing, &mut buf);
        assert_eq!(buf, schedule(&s2, LogicalRing));
        schedule_into(&spec(Collective::None, 1e9, 8, 8), LogicalRing, &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn allreduce_phase_counts() {
        let s = |ni, nx| spec(Collective::AllReduce, 1e9, ni, nx);
        assert_eq!(schedule(&s(8, 16), Hierarchical).len(), 4);
        assert_eq!(schedule(&s(8, 1), Hierarchical).len(), 2);
        assert_eq!(schedule(&s(1, 16), Hierarchical).len(), 2);
        assert_eq!(schedule(&s(8, 16), LogicalRing).len(), 2);
        // Flat ring crossing pods rides the inter-pod links.
        assert_eq!(
            schedule(&s(8, 16), LogicalRing)[0].link,
            LinkClass::InterPod
        );
        assert_eq!(
            schedule(&s(8, 1), LogicalRing)[0].link,
            LinkClass::IntraPod
        );
    }
}
