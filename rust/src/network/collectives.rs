//! Analytical collective cost model on a two-level (intra-pod / inter-pod)
//! topology with ring schedules per level — the paper's "Logical Ring"
//! collectives with BlueConnect/Themis-style hierarchical decomposition.
//!
//! Must stay numerically identical to the L1 Pallas kernel and the jnp
//! oracle (python/compile/kernels/{collective,ref}.py); the cross-layer
//! integration test enforces this.

use crate::config::MAX_TIERS;
use crate::workload::Collective;

/// Collective implementation (paper Table I vs SV-B4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum CollectiveImpl {
    /// "Logical Ring" (Table I baseline): one flat ring over all
    /// participants, serialized by the slowest link class it crosses.
    #[default]
    LogicalRing,
    /// Hierarchical (BlueConnect/Themis): per-level ring passes with the
    /// inter-pod stage operating on the intra-reduced shard. Used by the
    /// paper's network studies (Figs. 11-12).
    Hierarchical,
}

impl CollectiveImpl {
    /// ABI code (layout.py P_COLL_IMPL).
    pub fn code(self) -> f64 {
        match self {
            CollectiveImpl::LogicalRing => 0.0,
            CollectiveImpl::Hierarchical => 1.0,
        }
    }

    /// Canonical short name — the scenario-file vocabulary
    /// (`ring` | `hierarchical`) that labels and spec (de)serialization
    /// share.
    pub fn name(self) -> &'static str {
        match self {
            CollectiveImpl::LogicalRing => "ring",
            CollectiveImpl::Hierarchical => "hierarchical",
        }
    }
}

/// A fully resolved collective: payload, type, and group shape.
///
/// `n_intra`/`n_inter` carry the two-level shape every backend
/// understands. When the spec was resolved on an N-tier chain,
/// `n_tiers > 0` and `tier_n` carries the per-tier participant
/// fan-out (innermost first); `n_intra`/`n_inter` then hold the
/// two-level projection (tier 0 vs everything above) so two-class
/// backends such as the DES engine stay usable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveSpec {
    /// Collective type.
    pub collective: Collective,
    /// Payload bytes per participant.
    pub bytes: f64,
    /// Participants sharing a pod.
    pub n_intra: usize,
    /// Participant groups across pods.
    pub n_inter: usize,
    /// Active tiers in `tier_n` (0 = legacy two-level resolution).
    pub n_tiers: usize,
    /// Per-tier participant fan-out, innermost first; unused slots are 1.
    pub tier_n: [usize; MAX_TIERS],
}

impl CollectiveSpec {
    /// A legacy two-level spec (no tier annotation).
    pub fn two_level(
        collective: Collective,
        bytes: f64,
        n_intra: usize,
        n_inter: usize,
    ) -> Self {
        CollectiveSpec {
            collective,
            bytes,
            n_intra,
            n_inter,
            n_tiers: 0,
            tier_n: [1; MAX_TIERS],
        }
    }

    /// A tier-annotated spec; `n_intra`/`n_inter` are set to the
    /// two-level projection (tier 0 vs the product of outer tiers).
    pub fn tiered(
        collective: Collective,
        bytes: f64,
        tier_n: [usize; MAX_TIERS],
        n_tiers: usize,
    ) -> Self {
        let k = n_tiers.clamp(1, MAX_TIERS);
        let n_intra = tier_n[0].max(1);
        let n_inter = tier_n[1..k].iter().product::<usize>().max(1);
        CollectiveSpec {
            collective,
            bytes,
            n_intra,
            n_inter,
            n_tiers: k,
            tier_n,
        }
    }

    /// Total participants.
    pub fn n(&self) -> usize {
        if self.n_tiers > 0 {
            self.tier_n[..self.n_tiers].iter().product()
        } else {
            self.n_intra * self.n_inter
        }
    }
}

/// One ring pass (reduce-scatter or all-gather) over `n` peers at
/// per-node link bandwidth `bw`: `(n-1)/n x bytes / bw + (n-1) x lat`.
fn ring_pass(bytes: f64, n: f64, bw: f64, lat: f64) -> f64 {
    if n <= 1.0 {
        return 0.0;
    }
    (n - 1.0) / n * bytes / bw.max(1.0) + (n - 1.0) * lat
}

/// Cost (seconds) of a collective on the two-level topology.
///
/// * All-reduce, logical ring: `2 (n-1)/n x bytes / bw_flat` where
///   `bw_flat` is the inter-pod bandwidth when the ring crosses pods.
/// * All-reduce, hierarchical: intra-pod reduce-scatter, inter-pod
///   all-reduce of the `bytes / n_intra` shard, intra-pod all-gather.
///   Degenerate levels contribute zero, covering flat groups.
/// * All-to-all (either impl): intra- and inter-pod portions proceed
///   concurrently on their own link classes; cost is the max of the
///   serialization times.
/// * All-gather / reduce-scatter: a single ring pass (per level).
pub fn collective_cost(
    spec: &CollectiveSpec,
    bw_intra: f64,
    bw_inter: f64,
    lat: f64,
    impl_: CollectiveImpl,
) -> f64 {
    let n = spec.n() as f64;
    if spec.bytes <= 0.0 || n <= 1.0 {
        return 0.0;
    }
    let ni = spec.n_intra as f64;
    let nx = spec.n_inter as f64;
    let shard = spec.bytes / ni.max(1.0);
    let bw_flat = if spec.n_inter > 1 { bw_inter } else { bw_intra };
    match spec.collective {
        Collective::None => 0.0,
        Collective::AllReduce => match impl_ {
            CollectiveImpl::LogicalRing => {
                2.0 * ring_pass(spec.bytes, n, bw_flat, lat)
            }
            CollectiveImpl::Hierarchical => {
                ring_pass(spec.bytes, ni, bw_intra, lat)
                    + 2.0 * ring_pass(shard, nx, bw_inter, lat)
                    + ring_pass(spec.bytes, ni, bw_intra, lat)
            }
        },
        Collective::AllToAll => {
            let peers = (n - 1.0).max(1.0);
            let f_intra = (ni - 1.0).max(0.0) / peers;
            let f_inter = 1.0 - f_intra;
            (spec.bytes * f_intra / bw_intra.max(1.0))
                .max(spec.bytes * f_inter / bw_inter.max(1.0))
                + (n - 1.0) * lat
        }
        Collective::AllGather | Collective::ReduceScatter => match impl_ {
            CollectiveImpl::LogicalRing => ring_pass(spec.bytes, n, bw_flat, lat),
            CollectiveImpl::Hierarchical => {
                ring_pass(spec.bytes, ni, bw_intra, lat)
                    + ring_pass(shard, nx, bw_inter, lat)
            }
        },
    }
}

/// Index of the outermost tier an operation actually crosses: the
/// highest tier with more than one participant group (falling back to
/// tier 0). Generalizes the legacy `n_inter > 1 ? inter : intra` flat
/// link-class choice.
fn crossing_tier(spec: &CollectiveSpec, k: usize) -> usize {
    (0..k).rev().find(|&t| spec.tier_n[t] > 1).unwrap_or(0)
}

/// Cost (seconds) of a collective on an N-tier chain — the k-tier
/// generalization of [`collective_cost`].
///
/// * All-reduce, hierarchical: reduce-scatter up the chain (tier t
///   operates on the tier-(t-1)-reduced shard `bytes / prod(n_0..n_t)`),
///   a full all-reduce ring at the top tier, then all-gather back down.
///   At `k = 2` this is bit-identical to the legacy two-level cost.
/// * Logical-ring impls serialize one flat ring at the bandwidth of the
///   outermost tier the group crosses.
/// * All-to-all: each tier carries the fraction of peers first reachable
///   at that tier, concurrently; cost is the max serialization time plus
///   the flat latency term at the crossing tier.
pub fn collective_cost_tiered(
    spec: &CollectiveSpec,
    tier_bw: &[f64; MAX_TIERS],
    tier_lat: &[f64; MAX_TIERS],
    impl_: CollectiveImpl,
) -> f64 {
    let k = spec.n_tiers.clamp(1, MAX_TIERS);
    let n = spec.tier_n[..k].iter().product::<usize>() as f64;
    if spec.bytes <= 0.0 || n <= 1.0 {
        return 0.0;
    }
    // Shard size entering each tier: the payload already reduced by all
    // tiers below it.
    let mut shard = [0.0_f64; MAX_TIERS];
    let mut b = spec.bytes;
    for t in 0..k {
        shard[t] = b;
        b /= (spec.tier_n[t] as f64).max(1.0);
    }
    let cross = crossing_tier(spec, k);
    let (bw_flat, lat_flat) = (tier_bw[cross], tier_lat[cross]);
    match spec.collective {
        Collective::None => 0.0,
        Collective::AllReduce => match impl_ {
            CollectiveImpl::LogicalRing => {
                2.0 * ring_pass(spec.bytes, n, bw_flat, lat_flat)
            }
            CollectiveImpl::Hierarchical => {
                let mut acc = 0.0;
                for t in 0..k - 1 {
                    acc += ring_pass(
                        shard[t],
                        spec.tier_n[t] as f64,
                        tier_bw[t],
                        tier_lat[t],
                    );
                }
                acc += 2.0
                    * ring_pass(
                        shard[k - 1],
                        spec.tier_n[k - 1] as f64,
                        tier_bw[k - 1],
                        tier_lat[k - 1],
                    );
                for t in (0..k - 1).rev() {
                    acc += ring_pass(
                        shard[t],
                        spec.tier_n[t] as f64,
                        tier_bw[t],
                        tier_lat[t],
                    );
                }
                acc
            }
        },
        Collective::AllToAll => {
            let peers = (n - 1.0).max(1.0);
            // Fraction of peers first reachable at each tier; the last
            // tier takes the remainder so fractions sum to exactly 1.
            let mut within = 1.0_f64;
            let mut frac = [0.0_f64; MAX_TIERS];
            let mut below_last = 0.0;
            for (t, f) in frac.iter_mut().enumerate().take(k - 1) {
                let prev = within;
                within *= spec.tier_n[t] as f64;
                *f = if t == 0 {
                    (within - 1.0).max(0.0) / peers
                } else {
                    (within - prev).max(0.0) / peers
                };
                below_last += *f;
            }
            frac[k - 1] = 1.0 - below_last;
            let mut cost = spec.bytes * frac[0] / tier_bw[0].max(1.0);
            for t in 1..k {
                cost = cost.max(spec.bytes * frac[t] / tier_bw[t].max(1.0));
            }
            cost + (n - 1.0) * lat_flat
        }
        Collective::AllGather | Collective::ReduceScatter => match impl_ {
            CollectiveImpl::LogicalRing => {
                ring_pass(spec.bytes, n, bw_flat, lat_flat)
            }
            CollectiveImpl::Hierarchical => {
                let mut acc = 0.0;
                for t in 0..k {
                    acc += ring_pass(
                        shard[t],
                        spec.tier_n[t] as f64,
                        tier_bw[t],
                        tier_lat[t],
                    );
                }
                acc
            }
        },
    }
}

/// Dispatch on the spec's addressing: tier-annotated specs cost on the
/// chain, legacy specs cost on the two-level view. Keeps every legacy
/// call path bit-identical while letting tier-aware inputs flow through
/// the same evaluators.
#[allow(clippy::too_many_arguments)]
pub fn collective_cost_auto(
    spec: &CollectiveSpec,
    bw_intra: f64,
    bw_inter: f64,
    lat: f64,
    tier_bw: &[f64; MAX_TIERS],
    tier_lat: &[f64; MAX_TIERS],
    impl_: CollectiveImpl,
) -> f64 {
    if spec.n_tiers > 0 {
        collective_cost_tiered(spec, tier_bw, tier_lat, impl_)
    } else {
        collective_cost(spec, bw_intra, bw_inter, lat, impl_)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use CollectiveImpl::{Hierarchical, LogicalRing};

    fn ar(bytes: f64, ni: usize, nx: usize) -> CollectiveSpec {
        CollectiveSpec::two_level(Collective::AllReduce, bytes, ni, nx)
    }

    #[test]
    fn flat_ring_allreduce_closed_form() {
        let c = collective_cost(&ar(1e9, 8, 1), 300e9, 31.25e9, 0.0, Hierarchical);
        let want = 2.0 * 7.0 / 8.0 * 1e9 / 300e9;
        assert!((c - want).abs() / want < 1e-12);
    }

    #[test]
    fn inter_only_ring() {
        let c = collective_cost(&ar(1e9, 1, 16), 300e9, 31.25e9, 0.0, Hierarchical);
        let want = 2.0 * 15.0 / 16.0 * 1e9 / 31.25e9;
        assert!((c - want).abs() / want < 1e-12);
    }

    #[test]
    fn hierarchical_beats_flat_over_slow_links() {
        let hier =
            collective_cost(&ar(1e9, 8, 16), 300e9, 31.25e9, 0.0, Hierarchical);
        let flat =
            collective_cost(&ar(1e9, 8, 16), 300e9, 31.25e9, 0.0, LogicalRing);
        let want_flat = 2.0 * 127.0 / 128.0 * 1e9 / 31.25e9;
        assert!((flat - want_flat).abs() / want_flat < 1e-12);
        assert!(hier < flat, "hier {hier} flat {flat}");
    }

    #[test]
    fn singleton_group_free() {
        for impl_ in [LogicalRing, Hierarchical] {
            assert_eq!(
                collective_cost(&ar(1e9, 1, 1), 300e9, 31.25e9, 1e-6, impl_),
                0.0
            );
            assert_eq!(
                collective_cost(&ar(0.0, 8, 8), 300e9, 31.25e9, 1e-6, impl_),
                0.0
            );
        }
    }

    #[test]
    fn alltoall_balances_link_classes() {
        let spec = CollectiveSpec::two_level(Collective::AllToAll, 64e9, 8, 8);
        // 7/63 of peers intra, 56/63 inter.
        let c = collective_cost(&spec, 300e9, 31.25e9, 0.0, Hierarchical);
        let want = (64e9 * (56.0 / 63.0) / 31.25e9_f64)
            .max(64e9 * (7.0 / 63.0) / 300e9);
        assert!((c - want).abs() / want < 1e-12);
    }

    #[test]
    fn allgather_is_half_of_allreduce_flat() {
        let ag = CollectiveSpec::two_level(Collective::AllGather, 1e9, 8, 1);
        let arr = ar(1e9, 8, 1);
        let cag = collective_cost(&ag, 300e9, 31.25e9, 0.0, Hierarchical);
        let car = collective_cost(&arr, 300e9, 31.25e9, 0.0, Hierarchical);
        assert!((car / cag - 2.0).abs() < 1e-12);
    }

    #[test]
    fn latency_term_scales_with_steps() {
        let no_lat = collective_cost(&ar(1e6, 8, 1), 300e9, 31.25e9, 0.0, Hierarchical);
        let with_lat = collective_cost(&ar(1e6, 8, 1), 300e9, 31.25e9, 1e-6, Hierarchical);
        assert!((with_lat - no_lat - 14.0 * 1e-6).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_bytes_and_bandwidth() {
        let base = collective_cost(&ar(1e9, 8, 16), 300e9, 31.25e9, 1e-6, Hierarchical);
        assert!(collective_cost(&ar(2e9, 8, 16), 300e9, 31.25e9, 1e-6, Hierarchical) > base);
        assert!(collective_cost(&ar(1e9, 8, 16), 600e9, 62.5e9, 1e-6, Hierarchical) < base);
    }

    #[test]
    fn more_pods_cost_more() {
        let mut prev = 0.0;
        for nx in [1, 2, 4, 8, 16, 32] {
            let c = collective_cost(&ar(1e9, 8, nx), 300e9, 31.25e9, 1e-6, Hierarchical);
            assert!(c >= prev);
            prev = c;
        }
    }

    #[test]
    fn tiered_two_tiers_matches_legacy_bitwise() {
        let bw = [300e9, 31.25e9, 0.0, 0.0];
        let lat = [1e-6; 4];
        for coll in [
            Collective::AllReduce,
            Collective::AllGather,
            Collective::ReduceScatter,
            Collective::AllToAll,
        ] {
            for (ni, nx) in [(8, 16), (8, 1), (1, 16), (2, 2)] {
                let legacy = CollectiveSpec::two_level(coll, 3e9, ni, nx);
                let tiered =
                    CollectiveSpec::tiered(coll, 3e9, [ni, nx, 1, 1], 2);
                for impl_ in [LogicalRing, Hierarchical] {
                    let a =
                        collective_cost(&legacy, bw[0], bw[1], 1e-6, impl_);
                    let b = collective_cost_tiered(&tiered, &bw, &lat, impl_);
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{coll:?} {impl_:?} ni={ni} nx={nx}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn tiered_three_tier_allreduce_closed_form() {
        // 8x4x2 chain, hierarchical: rs/ag per lower tier plus a full
        // ring at the top on the twice-reduced shard.
        let spec =
            CollectiveSpec::tiered(Collective::AllReduce, 1e9, [8, 4, 2, 1], 3);
        let bw = [300e9, 50e9, 12.5e9, 0.0];
        let lat = [0.0; 4];
        let got = collective_cost_tiered(&spec, &bw, &lat, Hierarchical);
        let t0 = 7.0 / 8.0 * 1e9 / 300e9;
        let t1 = 3.0 / 4.0 * (1e9 / 8.0) / 50e9;
        let t2 = 2.0 * (1.0 / 2.0) * (1e9 / 32.0) / 12.5e9;
        let want = 2.0 * (t0 + t1) + t2;
        assert!((got - want).abs() / want < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn tiered_cost_monotone_in_every_tier_bandwidth() {
        let spec =
            CollectiveSpec::tiered(Collective::AllReduce, 1e9, [8, 4, 2, 1], 3);
        let bw = [300e9, 50e9, 12.5e9, 0.0];
        let lat = [1e-6; 4];
        for impl_ in [LogicalRing, Hierarchical] {
            let base = collective_cost_tiered(&spec, &bw, &lat, impl_);
            for t in 0..3 {
                let mut faster = bw;
                faster[t] *= 2.0;
                let c = collective_cost_tiered(&spec, &faster, &lat, impl_);
                assert!(c <= base, "tier {t} {impl_:?}: {c} > {base}");
            }
        }
    }
}
