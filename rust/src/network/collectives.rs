//! Analytical collective cost model on a two-level (intra-pod / inter-pod)
//! topology with ring schedules per level — the paper's "Logical Ring"
//! collectives with BlueConnect/Themis-style hierarchical decomposition.
//!
//! Must stay numerically identical to the L1 Pallas kernel and the jnp
//! oracle (python/compile/kernels/{collective,ref}.py); the cross-layer
//! integration test enforces this.

use crate::workload::Collective;

/// Collective implementation (paper Table I vs SV-B4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum CollectiveImpl {
    /// "Logical Ring" (Table I baseline): one flat ring over all
    /// participants, serialized by the slowest link class it crosses.
    #[default]
    LogicalRing,
    /// Hierarchical (BlueConnect/Themis): per-level ring passes with the
    /// inter-pod stage operating on the intra-reduced shard. Used by the
    /// paper's network studies (Figs. 11-12).
    Hierarchical,
}

impl CollectiveImpl {
    /// ABI code (layout.py P_COLL_IMPL).
    pub fn code(self) -> f64 {
        match self {
            CollectiveImpl::LogicalRing => 0.0,
            CollectiveImpl::Hierarchical => 1.0,
        }
    }

    /// Canonical short name — the scenario-file vocabulary
    /// (`ring` | `hierarchical`) that labels and spec (de)serialization
    /// share.
    pub fn name(self) -> &'static str {
        match self {
            CollectiveImpl::LogicalRing => "ring",
            CollectiveImpl::Hierarchical => "hierarchical",
        }
    }
}

/// A fully resolved collective: payload, type, and two-level group shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveSpec {
    /// Collective type.
    pub collective: Collective,
    /// Payload bytes per participant.
    pub bytes: f64,
    /// Participants sharing a pod.
    pub n_intra: usize,
    /// Participant groups across pods.
    pub n_inter: usize,
}

impl CollectiveSpec {
    /// Total participants.
    pub fn n(&self) -> usize {
        self.n_intra * self.n_inter
    }
}

/// One ring pass (reduce-scatter or all-gather) over `n` peers at
/// per-node link bandwidth `bw`: `(n-1)/n x bytes / bw + (n-1) x lat`.
fn ring_pass(bytes: f64, n: f64, bw: f64, lat: f64) -> f64 {
    if n <= 1.0 {
        return 0.0;
    }
    (n - 1.0) / n * bytes / bw.max(1.0) + (n - 1.0) * lat
}

/// Cost (seconds) of a collective on the two-level topology.
///
/// * All-reduce, logical ring: `2 (n-1)/n x bytes / bw_flat` where
///   `bw_flat` is the inter-pod bandwidth when the ring crosses pods.
/// * All-reduce, hierarchical: intra-pod reduce-scatter, inter-pod
///   all-reduce of the `bytes / n_intra` shard, intra-pod all-gather.
///   Degenerate levels contribute zero, covering flat groups.
/// * All-to-all (either impl): intra- and inter-pod portions proceed
///   concurrently on their own link classes; cost is the max of the
///   serialization times.
/// * All-gather / reduce-scatter: a single ring pass (per level).
pub fn collective_cost(
    spec: &CollectiveSpec,
    bw_intra: f64,
    bw_inter: f64,
    lat: f64,
    impl_: CollectiveImpl,
) -> f64 {
    let n = spec.n() as f64;
    if spec.bytes <= 0.0 || n <= 1.0 {
        return 0.0;
    }
    let ni = spec.n_intra as f64;
    let nx = spec.n_inter as f64;
    let shard = spec.bytes / ni.max(1.0);
    let bw_flat = if spec.n_inter > 1 { bw_inter } else { bw_intra };
    match spec.collective {
        Collective::None => 0.0,
        Collective::AllReduce => match impl_ {
            CollectiveImpl::LogicalRing => {
                2.0 * ring_pass(spec.bytes, n, bw_flat, lat)
            }
            CollectiveImpl::Hierarchical => {
                ring_pass(spec.bytes, ni, bw_intra, lat)
                    + 2.0 * ring_pass(shard, nx, bw_inter, lat)
                    + ring_pass(spec.bytes, ni, bw_intra, lat)
            }
        },
        Collective::AllToAll => {
            let peers = (n - 1.0).max(1.0);
            let f_intra = (ni - 1.0).max(0.0) / peers;
            let f_inter = 1.0 - f_intra;
            (spec.bytes * f_intra / bw_intra.max(1.0))
                .max(spec.bytes * f_inter / bw_inter.max(1.0))
                + (n - 1.0) * lat
        }
        Collective::AllGather | Collective::ReduceScatter => match impl_ {
            CollectiveImpl::LogicalRing => ring_pass(spec.bytes, n, bw_flat, lat),
            CollectiveImpl::Hierarchical => {
                ring_pass(spec.bytes, ni, bw_intra, lat)
                    + ring_pass(shard, nx, bw_inter, lat)
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use CollectiveImpl::{Hierarchical, LogicalRing};

    fn ar(bytes: f64, ni: usize, nx: usize) -> CollectiveSpec {
        CollectiveSpec {
            collective: Collective::AllReduce,
            bytes,
            n_intra: ni,
            n_inter: nx,
        }
    }

    #[test]
    fn flat_ring_allreduce_closed_form() {
        let c = collective_cost(&ar(1e9, 8, 1), 300e9, 31.25e9, 0.0, Hierarchical);
        let want = 2.0 * 7.0 / 8.0 * 1e9 / 300e9;
        assert!((c - want).abs() / want < 1e-12);
    }

    #[test]
    fn inter_only_ring() {
        let c = collective_cost(&ar(1e9, 1, 16), 300e9, 31.25e9, 0.0, Hierarchical);
        let want = 2.0 * 15.0 / 16.0 * 1e9 / 31.25e9;
        assert!((c - want).abs() / want < 1e-12);
    }

    #[test]
    fn hierarchical_beats_flat_over_slow_links() {
        let hier =
            collective_cost(&ar(1e9, 8, 16), 300e9, 31.25e9, 0.0, Hierarchical);
        let flat =
            collective_cost(&ar(1e9, 8, 16), 300e9, 31.25e9, 0.0, LogicalRing);
        let want_flat = 2.0 * 127.0 / 128.0 * 1e9 / 31.25e9;
        assert!((flat - want_flat).abs() / want_flat < 1e-12);
        assert!(hier < flat, "hier {hier} flat {flat}");
    }

    #[test]
    fn singleton_group_free() {
        for impl_ in [LogicalRing, Hierarchical] {
            assert_eq!(
                collective_cost(&ar(1e9, 1, 1), 300e9, 31.25e9, 1e-6, impl_),
                0.0
            );
            assert_eq!(
                collective_cost(&ar(0.0, 8, 8), 300e9, 31.25e9, 1e-6, impl_),
                0.0
            );
        }
    }

    #[test]
    fn alltoall_balances_link_classes() {
        let spec = CollectiveSpec {
            collective: Collective::AllToAll,
            bytes: 64e9,
            n_intra: 8,
            n_inter: 8,
        };
        // 7/63 of peers intra, 56/63 inter.
        let c = collective_cost(&spec, 300e9, 31.25e9, 0.0, Hierarchical);
        let want = (64e9 * (56.0 / 63.0) / 31.25e9_f64)
            .max(64e9 * (7.0 / 63.0) / 300e9);
        assert!((c - want).abs() / want < 1e-12);
    }

    #[test]
    fn allgather_is_half_of_allreduce_flat() {
        let ag = CollectiveSpec {
            collective: Collective::AllGather,
            bytes: 1e9,
            n_intra: 8,
            n_inter: 1,
        };
        let arr = ar(1e9, 8, 1);
        let cag = collective_cost(&ag, 300e9, 31.25e9, 0.0, Hierarchical);
        let car = collective_cost(&arr, 300e9, 31.25e9, 0.0, Hierarchical);
        assert!((car / cag - 2.0).abs() < 1e-12);
    }

    #[test]
    fn latency_term_scales_with_steps() {
        let no_lat = collective_cost(&ar(1e6, 8, 1), 300e9, 31.25e9, 0.0, Hierarchical);
        let with_lat = collective_cost(&ar(1e6, 8, 1), 300e9, 31.25e9, 1e-6, Hierarchical);
        assert!((with_lat - no_lat - 14.0 * 1e-6).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_bytes_and_bandwidth() {
        let base = collective_cost(&ar(1e9, 8, 16), 300e9, 31.25e9, 1e-6, Hierarchical);
        assert!(collective_cost(&ar(2e9, 8, 16), 300e9, 31.25e9, 1e-6, Hierarchical) > base);
        assert!(collective_cost(&ar(1e9, 8, 16), 600e9, 62.5e9, 1e-6, Hierarchical) < base);
    }

    #[test]
    fn more_pods_cost_more() {
        let mut prev = 0.0;
        for nx in [1, 2, 4, 8, 16, 32] {
            let c = collective_cost(&ar(1e9, 8, nx), 300e9, 31.25e9, 1e-6, Hierarchical);
            assert!(c >= prev);
            prev = c;
        }
    }
}
