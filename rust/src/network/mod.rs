//! Inter-node communication models (paper SIII-C3): collective cost on the
//! two-level topology view, and chunked collective schedules consumed by
//! the discrete-event backend.

pub mod chunking;
pub mod collectives;

pub use collectives::{
    collective_cost, collective_cost_auto, collective_cost_tiered,
    CollectiveImpl, CollectiveSpec,
};
