//! Per-node performance models (paper SIII-C1/C2): roofline compute delay,
//! the tiling memory-traffic model, and hybrid local+expanded memory
//! bandwidth (Eqn. 3).

pub mod hybrid;
pub mod roofline;
pub mod traffic;

pub use hybrid::{em_fraction, hybrid_bandwidth};
pub use roofline::{compute_delay, operational_intensity, perf_max};
pub use traffic::gemm_traffic;
