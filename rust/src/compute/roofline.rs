//! Roofline compute-delay model (paper SIII-C1, Eqns. 1-2; Williams et al.).

/// Operational intensity, FLOPs / byte (Eqn. 1).
pub fn operational_intensity(flops: f64, traffic_bytes: f64) -> f64 {
    if traffic_bytes <= 0.0 {
        f64::INFINITY
    } else {
        flops / traffic_bytes
    }
}

/// Attainable performance: `min(perf_peak, OI x bw_mem)` (Fig. 4).
pub fn perf_max(perf_peak: f64, oi: f64, bw_mem: f64) -> f64 {
    perf_peak.min(oi * bw_mem)
}

/// Compute delay of one layer phase (Eqn. 2), expressed in the numerically
/// robust time form: `max(flops / perf_peak, traffic / bw_mem)` — identical
/// to `flops / perf_max` wherever the latter is defined, and well-behaved
/// for pure data movement (flops == 0).
pub fn compute_delay(
    flops: f64,
    traffic_bytes: f64,
    perf_peak: f64,
    bw_mem: f64,
) -> f64 {
    let compute_t = flops / perf_peak.max(1.0);
    let memory_t = traffic_bytes / bw_mem.max(1.0);
    compute_t.max(memory_t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_bound_layer_hits_peak() {
        // OI far above the ridge point: delay = flops / perf_peak.
        let d = compute_delay(1e15, 1e9, 624e12, 2039e9);
        assert!((d - 1e15 / 624e12).abs() / d < 1e-12);
    }

    #[test]
    fn memory_bound_layer_hits_bandwidth() {
        let d = compute_delay(1e9, 1e12, 624e12, 2039e9);
        assert!((d - 1e12 / 2039e9).abs() / d < 1e-12);
    }

    #[test]
    fn ridge_point_continuous() {
        // At OI == perf_peak / bw both forms agree.
        let (pp, bw) = (624e12_f64, 2039e9_f64);
        let ridge_oi = pp / bw;
        let traffic = 1e9;
        let flops = ridge_oi * traffic;
        let d = compute_delay(flops, traffic, pp, bw);
        assert!((d - flops / pp).abs() / d < 1e-12);
        assert!((d - traffic / bw).abs() / d < 1e-12);
    }

    #[test]
    fn time_form_equals_perf_max_form() {
        for (flops, traffic) in
            [(1e12, 1e9), (1e9, 1e12), (5e11, 5e11), (1e15, 3.3e12)]
        {
            let (pp, bw) = (624e12, 2039e9);
            let oi = operational_intensity(flops, traffic);
            let via_perf = flops / perf_max(pp, oi, bw);
            let via_time = compute_delay(flops, traffic, pp, bw);
            assert!(
                (via_perf - via_time).abs() / via_time < 1e-12,
                "{flops} {traffic}"
            );
        }
    }

    #[test]
    fn zero_flops_is_pure_streaming() {
        let d = compute_delay(0.0, 1e9, 624e12, 2039e9);
        assert_eq!(d, 1e9 / 2039e9);
    }

    #[test]
    fn infinite_oi_for_zero_traffic() {
        assert!(operational_intensity(1.0, 0.0).is_infinite());
    }

    #[test]
    fn bandwidth_scaling_shifts_slope() {
        // Fig. 4: same OI, more bandwidth => lower delay in the
        // memory-bound region, no change when compute-bound.
        let mem_bound = |bw| compute_delay(1e9, 1e12, 624e12, bw);
        assert!(mem_bound(2039e9) < mem_bound(1000e9));
        let comp_bound = |bw| compute_delay(1e15, 1e6, 624e12, bw);
        assert_eq!(comp_bound(2039e9), comp_bound(1000e9));
    }
}
