//! Memory-traffic model for GEMMs on a node with a finite on-chip buffer
//! (paper SIII-C2): one operand is tiled into the buffer, the other is
//! streamed once per tile pass.
//!
//! For operands of U and V bytes, output W bytes, buffer S bytes:
//!   psi1 = ceil(U/S) * V + U     (tile U, stream V)
//!   psi2 = ceil(V/S) * U + V     (tile V, stream U)
//!   traffic = max(min(psi1, psi2), U + V) + W
//!
//! The `max(.., U+V)` clamp covers non-GEMM layers encoded with U = V = 0,
//! where every byte moves exactly once. Identical math to the L1 Pallas
//! kernel and the jnp oracle (python/compile/kernels/ref.py).

/// Memory traffic in bytes for one GEMM-shaped operation.
pub fn gemm_traffic(u: f64, v: f64, w: f64, sram: f64) -> f64 {
    let s = sram.max(1.0);
    let psi1 = (u / s).ceil() * v + u;
    let psi2 = (v / s).ceil() * u + v;
    psi1.min(psi2).max(u + v) + w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_in_buffer_moves_once() {
        // Both operands under S: each fetched once.
        let t = gemm_traffic(10e6, 20e6, 5e6, 40e6);
        assert_eq!(t, 10e6 + 20e6 + 5e6);
    }

    #[test]
    fn tiles_smaller_operand() {
        // Paper: for U < V, tiling U (psi1) moves ~V - U less data.
        let (u, v, w, s): (f64, f64, f64, f64) = (100e6, 1000e6, 1e6, 40e6);
        let psi1 = (u / s).ceil() * v + u;
        let psi2 = (v / s).ceil() * u + v;
        assert!(psi1 < psi2);
        assert_eq!(gemm_traffic(u, v, w, s), psi1 + w);
    }

    #[test]
    fn degenerate_streaming_layer() {
        // U = V = 0 (elementwise / lookup): traffic = W.
        assert_eq!(gemm_traffic(0.0, 0.0, 7e9, 40e6), 7e9);
    }

    #[test]
    fn one_sided_operand() {
        // U = 0, V > 0: V + W exactly once.
        assert_eq!(gemm_traffic(0.0, 5e9, 1e9, 40e6), 6e9);
    }

    #[test]
    fn bigger_buffer_never_more_traffic() {
        let mut prev = f64::INFINITY;
        for s in [1e6, 10e6, 40e6, 100e6, 1e9, 1e12] {
            let t = gemm_traffic(300e6, 700e6, 50e6, s);
            assert!(t <= prev + 1e-6, "S={s}");
            prev = t;
        }
    }

    #[test]
    fn lower_bound_is_touch_everything_once() {
        for (u, v, w) in [(1e9, 2e9, 3e9), (5e3, 1e8, 0.0), (0.0, 0.0, 1.0)] {
            assert!(gemm_traffic(u, v, w, 40e6) >= u + v + w);
        }
    }

    #[test]
    fn matches_paper_example_scale() {
        // A100-ish: MLP GEMM at MP8, rows=2048: U = 105 MB, V = 655 MB.
        let (u, v, w, s) = (104.9e6, 655.4e6, 419.4e6, 40e6);
        let t = gemm_traffic(u, v, w, s);
        // ceil(104.9/40) = 3 passes of V.
        assert!((t - (3.0 * v + u + w)).abs() < 1.0);
    }
}
