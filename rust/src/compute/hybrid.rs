//! Hybrid local + expanded memory bandwidth (paper SIII-C2, Eqn. 3).
//!
//! When the per-node footprint exceeds local-memory capacity, the excess
//! spills to expanded memory (host DRAM / CXL). Traffic splits
//! capacity-proportionally, and the effective bandwidth follows Eqn. 3:
//!
//!   bw_hybrid = total / (data_LM / bw_LM + data_EM / bw_EM)

/// Fraction of traffic served from expanded memory for a given footprint.
pub fn em_fraction(footprint: f64, cap_lm: f64) -> f64 {
    if footprint <= 0.0 {
        0.0
    } else {
        ((footprint - cap_lm) / footprint).clamp(0.0, 1.0)
    }
}

/// Effective bandwidth of the hybrid memory system (Eqn. 3).
///
/// `frac_em` in [0, 1]; when `frac_em == 0` this is exactly `bw_lm`.
/// With spill demanded but no expanded memory (`bw_em == 0`), the node is
/// starved: modelled as a 1 B/s floor, surfacing as a catastrophic delay
/// rather than a silent wrong answer.
pub fn hybrid_bandwidth(bw_lm: f64, bw_em: f64, frac_em: f64) -> f64 {
    if frac_em <= 0.0 {
        return bw_lm;
    }
    let bw_em = bw_em.max(1.0);
    let bw_lm = bw_lm.max(1.0);
    1.0 / ((1.0 - frac_em) / bw_lm + frac_em / bw_em)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_worked_example() {
        // SIII-C2: 240 GB accessed, 80 GB LM at 2 TB/s, EM at 1 TB/s
        // => 1.2 TB/s effective.
        let frac = em_fraction(240e9, 80e9);
        assert!((frac - 2.0 / 3.0).abs() < 1e-12);
        let bw = hybrid_bandwidth(2e12, 1e12, frac);
        assert!((bw - 1.2e12).abs() < 1e6, "{bw:.4e}");
    }

    #[test]
    fn no_spill_is_local_bandwidth() {
        assert_eq!(em_fraction(50e9, 80e9), 0.0);
        assert_eq!(hybrid_bandwidth(2039e9, 500e9, 0.0), 2039e9);
    }

    #[test]
    fn full_spill_is_em_bandwidth() {
        assert_eq!(hybrid_bandwidth(2039e9, 500e9, 1.0), 500e9);
    }

    #[test]
    fn bounded_by_the_two_levels() {
        for frac in [0.1, 0.3, 0.5, 0.9] {
            let bw = hybrid_bandwidth(2039e9, 500e9, frac);
            assert!(bw < 2039e9);
            assert!(bw > 500e9);
        }
    }

    #[test]
    fn monotone_in_em_bandwidth() {
        let f = 0.6;
        let mut prev = 0.0;
        for bw_em in [100e9, 250e9, 500e9, 1000e9, 2039e9] {
            let bw = hybrid_bandwidth(2039e9, bw_em, f);
            assert!(bw > prev);
            prev = bw;
        }
    }

    #[test]
    fn starved_without_expansion() {
        // Spill with no EM: effectively unusable (floor at ~1 B/s).
        let bw = hybrid_bandwidth(2039e9, 0.0, 0.5);
        assert!(bw < 3.0);
    }

    #[test]
    fn em_fraction_monotone_in_footprint() {
        let mut prev = -1.0;
        for fp in [10e9, 80e9, 160e9, 320e9, 640e9] {
            let f = em_fraction(fp, 80e9);
            assert!(f >= prev);
            prev = f;
        }
        assert_eq!(em_fraction(80e9, 80e9), 0.0);
    }
}
