//! Analytical goodput model: closed-form training efficiency under
//! failures, stragglers, and link degradation.
//!
//! `goodput = ideal_throughput x efficiency(mtbf, ckpt)`, with three
//! multiplicative efficiency factors:
//!
//! * **Checkpoint–restart** (`eff_ckpt`): with cluster MTBF `M`, a
//!   checkpoint write cost `delta = footprint / ckpt_bw`, and the
//!   Young/Daly optimal interval `tau = sqrt(2 delta M)`, the fraction
//!   of wall-clock spent on useful work is
//!   `(tau / (tau + delta)) * (1 - (restart + (tau + delta)/2) / M)`:
//!   the first factor is checkpoint-write overhead, the second the
//!   expected restart plus half-interval rework per failure.
//! * **Stragglers** (`eff_straggler`): collectives and pipeline stages
//!   gate on the slowest participant, so any straggler inflates the
//!   whole step by its slowdown factor: `1 / slowdown`.
//! * **Link degradation** (`eff_link`): only the exposed-communication
//!   share of the step stretches when links lose bandwidth, so
//!   `1 / (1 + (factor - 1) * comm_fraction)`.
//!
//! The product is clamped to `(MIN_EFFICIENCY, 1]`. The upper clamp is
//! what makes the optimizer's analytical lower bound admissible for the
//! goodput objective: `score = total / efficiency >= total >= bound`
//! holds bit-wise because dividing by a value in (0, 1] is a single
//! correctly-rounded, monotone operation (see `optimizer`).

use crate::analytical::TrainingBreakdown;
use crate::resilience::FaultModel;

/// Floor on the modeled efficiency; keeps goodput scores finite even in
/// regimes where the model predicts the cluster makes no progress.
pub const MIN_EFFICIENCY: f64 = 1e-12;

/// Resilience-efficiency breakdown for one (cluster, strategy) design
/// point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Goodput {
    /// Seconds to write one checkpoint (footprint over the effective
    /// checkpoint bandwidth). Zero when failures are disabled.
    pub ckpt_write_s: f64,
    /// Young/Daly optimal checkpoint interval in seconds (infinite when
    /// failures are disabled).
    pub ckpt_interval_s: f64,
    /// Cluster-level MTBF in seconds (infinite when disabled).
    pub mtbf_cluster_s: f64,
    /// Checkpoint–restart efficiency factor in [0, 1].
    pub eff_ckpt: f64,
    /// Straggler efficiency factor in (0, 1].
    pub eff_straggler: f64,
    /// Link-degradation efficiency factor in (0, 1].
    pub eff_link: f64,
    /// Overall efficiency: product of the factors, clamped to
    /// (`MIN_EFFICIENCY`, 1].
    pub efficiency: f64,
}

impl Goodput {
    /// Effective (goodput-adjusted) time for a step that ideally takes
    /// `total_s`: wall-clock seconds per unit of useful work.
    pub fn effective_time(&self, total_s: f64) -> f64 {
        total_s / self.efficiency
    }
}

/// Evaluate the goodput efficiency of one design point.
///
/// `ckpt_bytes` is the per-node checkpoint footprint (model, optimizer,
/// and residual state — the same footprint the memory planner places),
/// and `ckpt_bw` the effective checkpoint bandwidth, normally from
/// [`crate::resilience::checkpoint_bandwidth`].
pub fn analyze(
    fault: &FaultModel,
    n_nodes: usize,
    ckpt_bytes: f64,
    ckpt_bw: f64,
    breakdown: &TrainingBreakdown,
) -> Goodput {
    let m = fault.mtbf_cluster_s(n_nodes);

    let (ckpt_write_s, ckpt_interval_s, eff_ckpt) = if !m.is_finite() {
        // Failures disabled: no checkpoints, perfect efficiency. This
        // branch is exact (1.0, not approximately 1.0) so the disabled
        // slice stays bit-identical to the fault-free model.
        (0.0, f64::INFINITY, 1.0)
    } else {
        let delta = if ckpt_bw > 0.0 { ckpt_bytes / ckpt_bw } else { 0.0 };
        if delta > 0.0 {
            let tau = (2.0 * delta * m).sqrt();
            // Per renewal cycle of tau useful seconds: one write of
            // delta; per failure (every M seconds): a restart plus on
            // average half a cycle of rework.
            let waste = (fault.restart_s + (tau + delta) / 2.0) / m;
            let eff = (tau / (tau + delta)) * (1.0 - waste).max(0.0);
            (delta, tau, eff)
        } else {
            // Free checkpoints: only restart time is lost per failure.
            (0.0, f64::INFINITY, (1.0 - fault.restart_s / m).max(0.0))
        }
    };

    let eff_straggler = if fault.straggler_count(n_nodes) > 0 {
        1.0 / fault.straggler_slowdown
    } else {
        1.0
    };

    let eff_link = if fault.degraded_count(n_nodes) > 0 {
        1.0 / (1.0 + (fault.link_degrade_factor - 1.0)
            * breakdown.comm_fraction())
    } else {
        1.0
    };

    let efficiency =
        (eff_ckpt * eff_straggler * eff_link).clamp(MIN_EFFICIENCY, 1.0);

    Goodput {
        ckpt_write_s,
        ckpt_interval_s,
        mtbf_cluster_s: m,
        eff_ckpt,
        eff_straggler,
        eff_link,
        efficiency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breakdown(compute: f64, comm: f64) -> TrainingBreakdown {
        TrainingBreakdown {
            fp_compute: compute,
            fp_exposed_comm: comm,
            ig_compute: 0.0,
            ig_exposed_comm: 0.0,
            wg_compute: 0.0,
            wg_exposed_comm: 0.0,
            bubble: 0.0,
            pp_exposed_comm: 0.0,
        }
    }

    #[test]
    fn disabled_faults_give_exact_unit_efficiency() {
        let b = breakdown(1.0, 0.5);
        let g = analyze(&FaultModel::none(), 1024, 264e9, 31.25e9, &b);
        assert_eq!(g.efficiency, 1.0);
        assert_eq!(g.eff_ckpt, 1.0);
        assert_eq!(g.ckpt_write_s, 0.0);
        assert!(g.ckpt_interval_s.is_infinite());
        assert_eq!(g.effective_time(2.5), 2.5);
    }

    #[test]
    fn efficiency_is_monotone_in_mtbf() {
        let b = breakdown(1.0, 0.2);
        let mut prev = 0.0;
        for mtbf in [50.0, 200.0, 1000.0, 10_000.0, 1e6] {
            let f = FaultModel {
                mtbf_node_hours: mtbf,
                restart_s: 120.0,
                ..FaultModel::none()
            };
            let g = analyze(&f, 1024, 264e9, 31.25e9, &b);
            assert!(g.efficiency.is_finite());
            assert!(g.efficiency > 0.0 && g.efficiency <= 1.0);
            assert!(
                g.efficiency >= prev,
                "efficiency must grow with MTBF: {} < {prev} at {mtbf}h",
                g.efficiency
            );
            prev = g.efficiency;
        }
    }

    #[test]
    fn bigger_checkpoints_cost_more() {
        let b = breakdown(1.0, 0.2);
        let f = FaultModel {
            mtbf_node_hours: 200.0,
            restart_s: 60.0,
            ..FaultModel::none()
        };
        let small = analyze(&f, 1024, 70e9, 31.25e9, &b);
        let large = analyze(&f, 1024, 264e9, 31.25e9, &b);
        assert!(large.ckpt_write_s > small.ckpt_write_s);
        assert!(large.efficiency < small.efficiency);
        // Young/Daly: interval grows with the write cost.
        assert!(large.ckpt_interval_s > small.ckpt_interval_s);
    }

    #[test]
    fn straggler_and_link_factors() {
        let b = breakdown(1.0, 1.0); // comm_fraction = 0.5
        let f = FaultModel {
            straggler_frac: 0.25,
            straggler_slowdown: 2.0,
            link_degrade_frac: 0.1,
            link_degrade_factor: 3.0,
            ..FaultModel::none()
        };
        let g = analyze(&f, 64, 70e9, 31.25e9, &b);
        assert_eq!(g.eff_ckpt, 1.0);
        assert!((g.eff_straggler - 0.5).abs() < 1e-12);
        // 1 / (1 + (3 - 1) * 0.5) = 0.5
        assert!((g.eff_link - 0.5).abs() < 1e-12);
        assert!((g.efficiency - 0.25).abs() < 1e-12);
        assert!((g.effective_time(2.0) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn efficiency_never_hits_zero_or_nan() {
        let b = breakdown(1.0, 0.0);
        // MTBF so low the bracket goes negative: clamped to the floor.
        let f = FaultModel {
            mtbf_node_hours: 0.001,
            restart_s: 600.0,
            ..FaultModel::none()
        };
        let g = analyze(&f, 4096, 264e9, 31.25e9, &b);
        assert!(g.efficiency >= MIN_EFFICIENCY);
        assert!(g.efficiency.is_finite());
        assert!(g.effective_time(1.0).is_finite());
    }

    #[test]
    fn zero_cost_checkpoints_lose_only_restart_time() {
        let b = breakdown(1.0, 0.0);
        let f = FaultModel {
            mtbf_node_hours: 1.0,
            restart_s: 36.0,
            ..FaultModel::none()
        };
        // 1 node: M = 3600 s; restart 36 s => eff_ckpt = 0.99.
        let g = analyze(&f, 1, 0.0, 31.25e9, &b);
        assert!((g.eff_ckpt - 0.99).abs() < 1e-12, "{}", g.eff_ckpt);
    }
}
