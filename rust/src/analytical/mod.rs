//! Closed-form analytical backend — the Rust-native equivalent of
//! ASTRA-SIM's analytical network mode, and the f64 mirror of the AOT
//! artifact's math (python/compile/kernels/ref.py).
//!
//! Per layer and phase: roofline compute delay over the hybrid-memory
//! bandwidth (SIII-C1/C2) plus hierarchical collective cost (SIII-C3);
//! exposure per SIII-C4 — FP/IG collectives block, the WG data-parallel
//! collective overlaps with WG compute.

use crate::compute::{em_fraction, gemm_traffic, hybrid_bandwidth};
use crate::model::inputs::ModelInputs;
use crate::network::collective_cost;

/// Per-iteration training-time breakdown, seconds (the paper's Fig. 8a
/// stacked bars).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TrainingBreakdown {
    /// Forward-pass compute time.
    pub fp_compute: f64,
    /// Forward-pass exposed (blocking) communication.
    pub fp_exposed_comm: f64,
    /// Input-gradient compute time.
    pub ig_compute: f64,
    /// Input-gradient exposed communication.
    pub ig_exposed_comm: f64,
    /// Weight-gradient compute time.
    pub wg_compute: f64,
    /// Weight-gradient communication left exposed after overlap.
    pub wg_exposed_comm: f64,
}

impl TrainingBreakdown {
    /// Total iteration time.
    pub fn total(&self) -> f64 {
        self.fp_compute
            + self.fp_exposed_comm
            + self.ig_compute
            + self.ig_exposed_comm
            + self.wg_compute
            + self.wg_exposed_comm
    }

    /// Total compute time.
    pub fn compute(&self) -> f64 {
        self.fp_compute + self.ig_compute + self.wg_compute
    }

    /// Total exposed communication time.
    pub fn exposed_comm(&self) -> f64 {
        self.fp_exposed_comm + self.ig_exposed_comm + self.wg_exposed_comm
    }

    /// Fraction of the iteration spent on exposed communication (Fig. 8b).
    pub fn comm_fraction(&self) -> f64 {
        let t = self.total();
        if t <= 0.0 {
            0.0
        } else {
            self.exposed_comm() / t
        }
    }

    /// The six components as an array (artifact ABI order).
    pub fn as_array(&self) -> [f64; 6] {
        [
            self.fp_compute,
            self.fp_exposed_comm,
            self.ig_compute,
            self.ig_exposed_comm,
            self.wg_compute,
            self.wg_exposed_comm,
        ]
    }

    /// From the artifact ABI order.
    pub fn from_array(a: [f64; 6]) -> TrainingBreakdown {
        TrainingBreakdown {
            fp_compute: a[0],
            fp_exposed_comm: a[1],
            ig_compute: a[2],
            ig_exposed_comm: a[3],
            wg_compute: a[4],
            wg_exposed_comm: a[5],
        }
    }
}

/// Evaluate the analytical cost model over derived inputs.
pub fn evaluate(inputs: &ModelInputs) -> TrainingBreakdown {
    let p = &inputs.params;
    let frac_em = p
        .em_frac_override
        .unwrap_or_else(|| em_fraction(p.footprint, p.cap_lm));
    let bw_eff = hybrid_bandwidth(p.bw_lm, p.bw_em, frac_em);

    let mut compute = [0.0f64; 3];
    let mut comm = [0.0f64; 3];
    for layer in &inputs.layers {
        for phase in 0..3 {
            let q = &layer.q[phase];
            let traffic = gemm_traffic(q.u, q.v, q.w, p.sram);
            let delay = crate::compute::compute_delay(
                q.flops,
                traffic,
                p.perf_peak,
                bw_eff,
            );
            compute[phase] += layer.repeat * delay;
            // Fast path: most layer-phases carry no collective.
            if !matches!(
                layer.comm[phase].collective,
                crate::workload::Collective::None
            ) {
                comm[phase] += layer.repeat
                    * collective_cost(
                        &layer.comm[phase],
                        p.bw_intra,
                        p.bw_inter,
                        p.link_latency,
                        p.collective_impl,
                    );
            }
        }
    }

    let wg_exposed = if p.overlap_wg {
        (comm[2] - compute[2]).max(0.0)
    } else {
        comm[2]
    };
    TrainingBreakdown {
        fp_compute: compute[0],
        fp_exposed_comm: comm[0],
        ig_compute: compute[1],
        ig_exposed_comm: comm[1],
        wg_compute: compute[2],
        wg_exposed_comm: wg_exposed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::model::inputs::{derive_inputs, EvalOptions};
    use crate::parallel::Strategy;
    use crate::workload::transformer::Transformer;

    fn eval(mp: usize, dp: usize, opts: &EvalOptions) -> TrainingBreakdown {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1().build(&Strategy::new(mp, dp)).unwrap();
        evaluate(&derive_inputs(&w, &cluster, opts).unwrap())
    }

    fn fig8a_opts() -> EvalOptions {
        EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        }
    }

    #[test]
    fn breakdown_is_positive_and_finite() {
        let b = eval(8, 128, &fig8a_opts());
        for v in b.as_array() {
            assert!(v.is_finite() && v >= 0.0, "{b:?}");
        }
        assert!(b.total() > 0.0);
    }

    #[test]
    fn fig8a_mp8_dp128_is_optimal() {
        // The paper's headline Fig. 8 result: MP8_DP128 minimizes iteration
        // time under infinite-capacity assumptions on the baseline cluster.
        let opts = fig8a_opts();
        let sweep = Strategy::sweep_bounded(1024, 1, 128);
        let best = sweep
            .iter()
            .min_by(|a, b| {
                let ta = eval(a.mp, a.dp, &opts).total();
                let tb = eval(b.mp, b.dp, &opts).total();
                ta.partial_cmp(&tb).unwrap()
            })
            .unwrap();
        assert_eq!((best.mp, best.dp), (8, 128), "best {}", best.label());
    }

    #[test]
    fn fig8_high_mp_is_comm_bound() {
        let b = eval(64, 16, &fig8a_opts());
        assert!(
            b.exposed_comm() > b.compute(),
            "MP64 must be communication-bound: {b:?}"
        );
    }

    #[test]
    fn fig8_low_mp_is_compute_bound() {
        let b = eval(2, 512, &fig8a_opts());
        assert!(
            b.compute() > 5.0 * b.exposed_comm(),
            "MP2 must be compute/memory-bound: {b:?}"
        );
    }

    #[test]
    fn fig8_wg_comm_fully_overlapped() {
        // Paper: "WG communication is fully overlapped by the WG compute in
        // every configuration".
        for s in Strategy::sweep_bounded(1024, 2, 128) {
            let b = eval(s.mp, s.dp, &fig8a_opts());
            assert_eq!(b.wg_exposed_comm, 0.0, "{}: {b:?}", s.label());
        }
    }

    #[test]
    fn overlap_off_exposes_wg() {
        let opts = EvalOptions {
            overlap_wg: false,
            ignore_capacity: true,
            ..Default::default()
        };
        let b = eval(8, 128, &opts);
        assert!(b.wg_exposed_comm > 0.0);
    }

    #[test]
    fn comm_fraction_decreases_with_mp() {
        // Fig. 8b: communication share shrinks monotonically as MP falls.
        let opts = fig8a_opts();
        let f64_ = eval(64, 16, &opts).comm_fraction();
        let f8 = eval(8, 128, &opts).comm_fraction();
        let f2 = eval(2, 512, &opts).comm_fraction();
        assert!(f64_ > f8, "{f64_} {f8}");
        assert!(f8 > f2, "{f8} {f2}");
    }

    #[test]
    fn spill_hurts_when_capacity_enforced() {
        // With capacity enforced and no EM, MP8's 264 GB footprint starves.
        let enforced = eval(8, 128, &EvalOptions::default());
        let infinite = eval(8, 128, &fig8a_opts());
        assert!(enforced.total() > infinite.total());
    }

    #[test]
    fn array_roundtrip() {
        let b = eval(8, 128, &fig8a_opts());
        let b2 = TrainingBreakdown::from_array(b.as_array());
        assert_eq!(b, b2);
    }
}
