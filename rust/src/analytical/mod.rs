//! Closed-form analytical backend — the Rust-native equivalent of
//! ASTRA-SIM's analytical network mode, and the f64 mirror of the AOT
//! artifact's math (python/compile/kernels/ref.py).
//!
//! Per layer and phase: roofline compute delay over the hybrid-memory
//! bandwidth (SIII-C1/C2) plus hierarchical collective cost (SIII-C3);
//! exposure per SIII-C4 — FP/IG collectives block, the WG data-parallel
//! collective overlaps with WG compute.
//!
//! **Pipeline parallelism (`pp > 1`)**: per-layer math is unchanged, but
//! layers accumulate into their pipeline stage, and the stages compose
//! through the fill–drain schedule recurrence [`pipeline_makespan`] —
//! per-microbatch stage times on serial stage resources, point-to-point
//! activation transfers on FIFO boundary links at the stage-boundary
//! link class. For balanced stages the extra time over the bottleneck
//! stage's own work is the classical bubble fraction `(pp - 1) / m` of
//! `m` microbatches (GPipe and 1F1B share it; they differ in activation
//! memory, which is folded into the derived footprint upstream). The
//! `pp = 1` slice takes the original code path untouched.

pub mod goodput;

use crate::compute::{em_fraction, gemm_traffic, hybrid_bandwidth};
use crate::model::inputs::{LayerRecord, ModelInputs, NodeParams};
use crate::network::{collective_cost_auto, CollectiveSpec};

/// One layer-phase collective under the params' addressing: tiered
/// resolution costs on the chain, legacy resolution on the two-level
/// view (bit-identical to the historical direct call).
pub(crate) fn layer_collective_cost(c: &CollectiveSpec, p: &NodeParams) -> f64 {
    collective_cost_auto(
        c,
        p.bw_intra,
        p.bw_inter,
        p.link_latency,
        &p.tier_bw,
        &p.tier_lat,
        p.collective_impl,
    )
}

/// Bandwidth and latency of the stage-boundary point-to-point link under
/// the params' addressing (legacy: the `pp_inter` link class; tiered:
/// the boundary tier).
pub(crate) fn pp_boundary_link(p: &NodeParams) -> (f64, f64) {
    if p.n_tiers > 0 {
        let t = p.pp_tier.min(p.n_tiers.saturating_sub(1));
        (p.tier_bw[t], p.tier_lat[t])
    } else if p.pp_inter {
        (p.bw_inter, p.link_latency)
    } else {
        (p.bw_intra, p.link_latency)
    }
}

/// Per-iteration training-time breakdown, seconds (the paper's Fig. 8a
/// stacked bars). With pipeline parallelism the six phase components
/// describe the **bottleneck stage**, and the two pipeline terms account
/// for everything the schedule adds on top; both are exactly zero on the
/// `pp = 1` slice.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TrainingBreakdown {
    /// Forward-pass compute time.
    pub fp_compute: f64,
    /// Forward-pass exposed (blocking) communication.
    pub fp_exposed_comm: f64,
    /// Input-gradient compute time.
    pub ig_compute: f64,
    /// Input-gradient exposed communication.
    pub ig_exposed_comm: f64,
    /// Weight-gradient compute time.
    pub wg_compute: f64,
    /// Weight-gradient communication left exposed after overlap.
    pub wg_exposed_comm: f64,
    /// Pipeline bubble: fill/drain + stage-imbalance idle time of the
    /// bottleneck stage (0 when `pp = 1`).
    pub bubble: f64,
    /// Exposed stage-boundary point-to-point activation-transfer time
    /// (0 when `pp = 1`).
    pub pp_exposed_comm: f64,
}

impl TrainingBreakdown {
    /// Total iteration time (phase components + pipeline terms).
    pub fn total(&self) -> f64 {
        self.fp_compute
            + self.fp_exposed_comm
            + self.ig_compute
            + self.ig_exposed_comm
            + self.wg_compute
            + self.wg_exposed_comm
            + self.bubble
            + self.pp_exposed_comm
    }

    /// Total compute time.
    pub fn compute(&self) -> f64 {
        self.fp_compute + self.ig_compute + self.wg_compute
    }

    /// Total exposed communication time (collectives + stage-boundary
    /// transfers; the bubble is idle, not communication).
    pub fn exposed_comm(&self) -> f64 {
        self.fp_exposed_comm
            + self.ig_exposed_comm
            + self.wg_exposed_comm
            + self.pp_exposed_comm
    }

    /// Fraction of the iteration spent on exposed communication (Fig. 8b).
    pub fn comm_fraction(&self) -> f64 {
        let t = self.total();
        if t <= 0.0 {
            0.0
        } else {
            self.exposed_comm() / t
        }
    }

    /// The six phase components as an array (artifact ABI order; the
    /// pipeline terms are not part of the ABI — the artifact backend
    /// rejects `pp > 1` inputs).
    pub fn as_array(&self) -> [f64; 6] {
        [
            self.fp_compute,
            self.fp_exposed_comm,
            self.ig_compute,
            self.ig_exposed_comm,
            self.wg_compute,
            self.wg_exposed_comm,
        ]
    }

    /// From the artifact ABI order (pipeline terms zero).
    pub fn from_array(a: [f64; 6]) -> TrainingBreakdown {
        TrainingBreakdown {
            fp_compute: a[0],
            fp_exposed_comm: a[1],
            ig_compute: a[2],
            ig_exposed_comm: a[3],
            wg_compute: a[4],
            wg_exposed_comm: a[5],
            bubble: 0.0,
            pp_exposed_comm: 0.0,
        }
    }
}

/// Makespan of the fill–drain (GPipe-style) pipeline schedule: `m`
/// microbatches with per-microbatch forward times `u[s]` and backward
/// times `b[s]` per stage, and a per-hop boundary transfer time `x`.
/// Stage compute is a serial resource; each stage boundary is a FIFO
/// link (transfers serialize), exactly the semantics the DES executes.
///
/// For balanced stages (`u[s] + b[s] = t`, `x = 0`) this evaluates to
/// `(m + pp - 1) * t` — the classical `(pp - 1) / m` bubble fraction.
/// The recurrence is monotone non-decreasing in every `u`, `b`, and `x`
/// (compositions of `max` and `+`), which is what makes the optimizer's
/// compute-floor pipeline bounds admissible bit-for-bit.
pub fn pipeline_makespan(u: &[f64], b: &[f64], x: f64, m: usize) -> f64 {
    let pp = u.len();
    if pp == 0 {
        return 0.0;
    }
    debug_assert_eq!(b.len(), pp);
    // Per-stage compute frontier; boundary-link FIFO frontiers.
    let mut stage = vec![0.0f64; pp];
    let mut link = vec![0.0f64; pp.saturating_sub(1)];
    for _ in 0..m {
        let mut carry = 0.0f64;
        for s in 0..pp {
            let arrive = if s == 0 {
                0.0
            } else {
                let t = carry.max(link[s - 1]) + x;
                link[s - 1] = t;
                t
            };
            stage[s] = arrive.max(stage[s]) + u[s];
            carry = stage[s];
        }
    }
    // Backward drains in reverse; a stage starts backward only after its
    // forward work (stage[s] frontier) is done.
    for _ in 0..m {
        let mut carry = 0.0f64;
        for s in (0..pp).rev() {
            let arrive = if s == pp - 1 {
                0.0
            } else {
                let t = carry.max(link[s]) + x;
                link[s] = t;
                t
            };
            stage[s] = arrive.max(stage[s]) + b[s];
            carry = stage[s];
        }
    }
    stage[0]
}

/// Evaluate the analytical cost model over derived inputs.
pub fn evaluate(inputs: &ModelInputs) -> TrainingBreakdown {
    evaluate_parts(&inputs.layers, &inputs.params)
}

/// Evaluate from borrowed parts — identical math to [`evaluate`], split
/// so callers that reuse one resolved layer list across many parameter
/// points (the optimizer's leaf fast path: branch-invariant
/// [`LayerRecord`]s, per-leaf stack-copied [`NodeParams`]) can evaluate
/// without building a [`ModelInputs`] per point. Bit-for-bit the same
/// result as `evaluate` on the assembled inputs.
pub fn evaluate_parts(
    layers: &[LayerRecord],
    p: &NodeParams,
) -> TrainingBreakdown {
    let frac_em = p
        .em_frac_override
        .unwrap_or_else(|| em_fraction(p.footprint, p.cap_lm));
    let bw_eff = hybrid_bandwidth(p.bw_lm, p.bw_em, frac_em);
    if p.pp <= 1 {
        evaluate_flat(layers, p, bw_eff)
    } else {
        evaluate_pipeline(layers, p, bw_eff)
    }
}

/// The original 2D (`pp = 1`) evaluation — bit-for-bit the pre-pipeline
/// code path; every pinned figure reproduces through here.
fn evaluate_flat(
    layers: &[LayerRecord],
    p: &NodeParams,
    bw_eff: f64,
) -> TrainingBreakdown {
    let mut compute = [0.0f64; 3];
    let mut comm = [0.0f64; 3];
    for layer in layers {
        for phase in 0..3 {
            let q = &layer.q[phase];
            let traffic = gemm_traffic(q.u, q.v, q.w, p.sram);
            let delay = crate::compute::compute_delay(
                q.flops,
                traffic,
                p.perf_peak,
                bw_eff,
            );
            compute[phase] += layer.repeat * delay;
            // Fast path: most layer-phases carry no collective.
            if !matches!(
                layer.comm[phase].collective,
                crate::workload::Collective::None
            ) {
                comm[phase] += layer.repeat
                    * layer_collective_cost(&layer.comm[phase], p);
            }
        }
    }

    let wg_exposed = if p.overlap_wg {
        (comm[2] - compute[2]).max(0.0)
    } else {
        comm[2]
    };
    TrainingBreakdown {
        fp_compute: compute[0],
        fp_exposed_comm: comm[0],
        ig_compute: compute[1],
        ig_exposed_comm: comm[1],
        wg_compute: compute[2],
        wg_exposed_comm: wg_exposed,
        bubble: 0.0,
        pp_exposed_comm: 0.0,
    }
}

/// Per-stage accumulation + the fill–drain schedule composition.
fn evaluate_pipeline(
    layers: &[LayerRecord],
    p: &NodeParams,
    bw_eff: f64,
) -> TrainingBreakdown {
    let pp = p.pp;
    let m = p.microbatches.max(1);
    let mf = m as f64;

    // Per-stage per-phase accumulation: the same per-layer math as the
    // flat path, bucketed by the layer's pipeline stage.
    let mut compute = vec![[0.0f64; 3]; pp];
    let mut comm = vec![[0.0f64; 3]; pp];
    for layer in layers {
        let s = layer.stage.min(pp - 1);
        for phase in 0..3 {
            let q = &layer.q[phase];
            let traffic = gemm_traffic(q.u, q.v, q.w, p.sram);
            let delay = crate::compute::compute_delay(
                q.flops,
                traffic,
                p.perf_peak,
                bw_eff,
            );
            compute[s][phase] += layer.repeat * delay;
            if !matches!(
                layer.comm[phase].collective,
                crate::workload::Collective::None
            ) {
                comm[s][phase] += layer.repeat
                    * layer_collective_cost(&layer.comm[phase], p);
            }
        }
    }

    // Per-microbatch stage service times; per-microbatch boundary hop.
    let u: Vec<f64> = (0..pp)
        .map(|s| (compute[s][0] + comm[s][0]) / mf)
        .collect();
    let b: Vec<f64> = (0..pp)
        .map(|s| (compute[s][1] + comm[s][1] + compute[s][2]) / mf)
        .collect();
    let (bw_b, lat_b) = pp_boundary_link(p);
    let x = (p.pp_boundary_bytes / mf) / bw_b.max(1.0) + lat_b;

    // Bottleneck stage: largest per-microbatch service (ties -> lowest
    // stage index, matching the DES).
    let mut btl = 0usize;
    for s in 1..pp {
        if u[s] + b[s] > u[btl] + b[btl] {
            btl = s;
        }
    }
    let wg_exp: Vec<f64> = (0..pp)
        .map(|s| {
            if p.overlap_wg {
                (comm[s][2] - compute[s][2]).max(0.0)
            } else {
                comm[s][2]
            }
        })
        .collect();

    let total = pipeline_makespan(&u, &b, x, m) + wg_exp[btl];
    // Bottleneck-stage busy time (full iteration, all phases + exposure).
    let busy = compute[btl][0]
        + comm[btl][0]
        + compute[btl][1]
        + comm[btl][1]
        + compute[btl][2]
        + wg_exp[btl];
    // Whatever the schedule adds over the bottleneck's own work splits
    // into exposed boundary transfers (capped at the critical-path
    // 2 (pp - 1) hops) and bubble idle; both clamps guard f64 rounding.
    let slack = (total - busy).max(0.0);
    let pp_exposed = slack.min(2.0 * (pp as f64 - 1.0) * x);
    let bubble = slack - pp_exposed;

    TrainingBreakdown {
        fp_compute: compute[btl][0],
        fp_exposed_comm: comm[btl][0],
        ig_compute: compute[btl][1],
        ig_exposed_comm: comm[btl][1],
        wg_compute: compute[btl][2],
        wg_exposed_comm: wg_exp[btl],
        bubble,
        pp_exposed_comm: pp_exposed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::model::inputs::{derive_inputs, EvalOptions};
    use crate::parallel::Strategy;
    use crate::workload::transformer::Transformer;

    fn eval(mp: usize, dp: usize, opts: &EvalOptions) -> TrainingBreakdown {
        let cluster = presets::dgx_a100_1024();
        let w = Transformer::t1()
            .build(&Strategy::new(mp, dp).unwrap())
            .unwrap();
        evaluate(&derive_inputs(&w, &cluster, opts).unwrap())
    }

    fn fig8a_opts() -> EvalOptions {
        EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        }
    }

    #[test]
    fn breakdown_is_positive_and_finite() {
        let b = eval(8, 128, &fig8a_opts());
        for v in b.as_array() {
            assert!(v.is_finite() && v >= 0.0, "{b:?}");
        }
        assert!(b.total() > 0.0);
    }

    #[test]
    fn fig8a_mp8_dp128_is_optimal() {
        // The paper's headline Fig. 8 result: MP8_DP128 minimizes iteration
        // time under infinite-capacity assumptions on the baseline cluster.
        let opts = fig8a_opts();
        let sweep = Strategy::sweep_bounded(1024, 1, 128).unwrap();
        let best = sweep
            .iter()
            .min_by(|a, b| {
                let ta = eval(a.mp, a.dp, &opts).total();
                let tb = eval(b.mp, b.dp, &opts).total();
                ta.partial_cmp(&tb).unwrap()
            })
            .unwrap();
        assert_eq!((best.mp, best.dp), (8, 128), "best {}", best.label());
    }

    #[test]
    fn fig8_high_mp_is_comm_bound() {
        let b = eval(64, 16, &fig8a_opts());
        assert!(
            b.exposed_comm() > b.compute(),
            "MP64 must be communication-bound: {b:?}"
        );
    }

    #[test]
    fn fig8_low_mp_is_compute_bound() {
        let b = eval(2, 512, &fig8a_opts());
        assert!(
            b.compute() > 5.0 * b.exposed_comm(),
            "MP2 must be compute/memory-bound: {b:?}"
        );
    }

    #[test]
    fn fig8_wg_comm_fully_overlapped() {
        // Paper: "WG communication is fully overlapped by the WG compute in
        // every configuration".
        for s in Strategy::sweep_bounded(1024, 2, 128).unwrap() {
            let b = eval(s.mp, s.dp, &fig8a_opts());
            assert_eq!(b.wg_exposed_comm, 0.0, "{}: {b:?}", s.label());
        }
    }

    #[test]
    fn overlap_off_exposes_wg() {
        let opts = EvalOptions {
            overlap_wg: false,
            ignore_capacity: true,
            ..Default::default()
        };
        let b = eval(8, 128, &opts);
        assert!(b.wg_exposed_comm > 0.0);
    }

    #[test]
    fn comm_fraction_decreases_with_mp() {
        // Fig. 8b: communication share shrinks monotonically as MP falls.
        let opts = fig8a_opts();
        let f64_ = eval(64, 16, &opts).comm_fraction();
        let f8 = eval(8, 128, &opts).comm_fraction();
        let f2 = eval(2, 512, &opts).comm_fraction();
        assert!(f64_ > f8, "{f64_} {f8}");
        assert!(f8 > f2, "{f8} {f2}");
    }

    #[test]
    fn spill_hurts_when_capacity_enforced() {
        // With capacity enforced and no EM, MP8's 264 GB footprint starves.
        let enforced = eval(8, 128, &EvalOptions::default());
        let infinite = eval(8, 128, &fig8a_opts());
        assert!(enforced.total() > infinite.total());
    }

    #[test]
    fn array_roundtrip() {
        let b = eval(8, 128, &fig8a_opts());
        let b2 = TrainingBreakdown::from_array(b.as_array());
        assert_eq!(b, b2);
    }

    fn eval_pipe(pp: usize, opts: &EvalOptions) -> TrainingBreakdown {
        let cluster = presets::dgx_a100_1024();
        let s = Strategy::new_3d(8, 128 / pp, pp).unwrap();
        let w = Transformer::t1().build(&s).unwrap();
        evaluate(&derive_inputs(&w, &cluster, opts).unwrap())
    }

    #[test]
    fn pipeline_makespan_balanced_is_bubble_formula() {
        // u + b = 1 per stage, free transfers: (m + pp - 1) * 1.
        for (pp, m) in [(2usize, 4usize), (4, 8), (8, 2), (8, 1)] {
            let u = vec![0.25; pp];
            let b = vec![0.75; pp];
            let got = pipeline_makespan(&u, &b, 0.0, m);
            let want = (m + pp - 1) as f64;
            assert!((got - want).abs() < 1e-9, "pp={pp} m={m}: {got}");
        }
        // Degenerate single stage: m services of u + b.
        assert_eq!(pipeline_makespan(&[2.0], &[3.0], 10.0, 4), 20.0);
    }

    #[test]
    fn pipeline_makespan_transfer_bound_corner() {
        // When the boundary hop dominates, the FIFO links serialize the
        // microbatches: makespan grows with m * x, not just (pp - 1) x.
        let pp = 4;
        let u = vec![1e-6; pp];
        let b = vec![1e-6; pp];
        let x = 1.0;
        let m = 16;
        let got = pipeline_makespan(&u, &b, x, m);
        // Forward + backward critical path alone is 2 (pp - 1) x; the
        // serialized microbatch train adds ~2 (m - 1) x on the busiest
        // boundary.
        assert!(got >= 2.0 * (pp as f64 - 1.0) * x);
        assert!(got >= (m as f64) * x, "{got}");
    }

    #[test]
    fn pipeline_makespan_monotone() {
        let u = [0.3, 0.5, 0.4];
        let b = [0.6, 0.2, 0.7];
        let base = pipeline_makespan(&u, &b, 0.01, 8);
        let mut u2 = u;
        u2[1] *= 2.0;
        assert!(pipeline_makespan(&u2, &b, 0.01, 8) >= base);
        assert!(pipeline_makespan(&u, &b, 0.02, 8) >= base);
        assert!(pipeline_makespan(&u, &b, 0.01, 9) >= base);
    }

    #[test]
    fn pp1_breakdown_has_no_pipeline_terms() {
        let b = eval(8, 128, &fig8a_opts());
        assert_eq!(b.bubble, 0.0);
        assert_eq!(b.pp_exposed_comm, 0.0);
    }

    #[test]
    fn pipeline_bubble_shrinks_with_microbatches() {
        let opts = |m: usize| EvalOptions {
            ignore_capacity: true,
            microbatches: m,
            ..Default::default()
        };
        let few = eval_pipe(8, &opts(2));
        let many = eval_pipe(8, &opts(32));
        assert!(few.bubble > 0.0, "{few:?}");
        assert!(
            few.total() > many.total(),
            "m=2 {} vs m=32 {}",
            few.total(),
            many.total()
        );
        // The bubble share tracks (pp - 1) / m for the balanced split.
        let share = few.bubble / few.total();
        assert!(share > 0.5, "bubble share {share}");
        let share_many = many.bubble / many.total();
        assert!(share_many < 0.25, "bubble share {share_many}");
    }

    #[test]
    fn pipeline_total_bounded_below_by_stage_work() {
        let opts = EvalOptions {
            ignore_capacity: true,
            microbatches: 8,
            ..Default::default()
        };
        for pp in [2usize, 4, 8] {
            let b = eval_pipe(pp, &opts);
            let stage_work = b.compute()
                + b.fp_exposed_comm
                + b.ig_exposed_comm
                + b.wg_exposed_comm;
            assert!(b.total() >= stage_work, "pp={pp}: {b:?}");
            assert!(b.bubble >= 0.0 && b.pp_exposed_comm >= 0.0);
        }
    }

    #[test]
    fn pipeline_fits_where_2d_starves() {
        // Capacity-enforced, no expanded memory: MP8_DP128 spills 264 GB
        // and starves; MP8_DP16_PP8 holds a 1/64 shard and runs at full
        // local bandwidth. This is the lattice-generalization headline.
        let opts = EvalOptions {
            microbatches: 8,
            ..Default::default()
        };
        let starved = eval(8, 128, &opts);
        let piped = eval_pipe(8, &opts);
        assert!(
            piped.total() < 0.01 * starved.total(),
            "piped {} vs starved {}",
            piped.total(),
            starved.total()
        );
    }
}
