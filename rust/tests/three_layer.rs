//! Three-layer integration: the AOT artifact (L1 Pallas kernels + L2 JAX
//! graph, executed via PJRT) must agree with the native Rust closed form
//! and the discrete-event simulator on every workload family.
//!
//! The artifact comparisons run only when `make artifacts` has produced
//! the AOT artifacts AND the build links the real `xla` PJRT bindings
//! (offline builds ship a stub — see `runtime::xla_stub`); otherwise they
//! skip with a note. The native-vs-DES cross-checks always run.

use comet::config::presets;
use comet::coordinator::Coordinator;
use comet::model::inputs::{derive_inputs, EvalOptions};
use comet::parallel::Strategy;
use comet::runtime::{BatchEvaluator, Runtime};
use comet::util::stats::rel_diff;
use comet::workload::dlrm::Dlrm;
use comet::workload::transformer::Transformer;

/// Artifact-capable CI sets `COMET_REQUIRE_ARTIFACTS=1` to turn these
/// skips back into the seed's hard failures — otherwise a batching or
/// chunking regression could hide behind a permanently-skipping suite.
/// NOTE: an artifact-capable build also needs the real `xla` bindings
/// swapped in for `runtime/xla_stub.rs` (one `use` line in
/// `runtime/client.rs`) in addition to `make artifacts`; with the stub,
/// this env var turns the skips into loud failures, which is the point.
fn artifacts_required() -> bool {
    std::env::var("COMET_REQUIRE_ARTIFACTS").as_deref() == Ok("1")
}

fn runtime() -> Option<Runtime> {
    match Runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) if artifacts_required() => {
            panic!("COMET_REQUIRE_ARTIFACTS=1 but artifact runtime failed: {e}")
        }
        Err(e) => {
            eprintln!("skipping artifact comparison ({e})");
            None
        }
    }
}

#[test]
fn artifact_matches_native_full_transformer_sweep() {
    let Some(rt) = runtime() else { return };
    let ev = BatchEvaluator::new(&rt);
    let cluster = presets::dgx_a100_1024();
    for ignore_capacity in [false, true] {
        let opts = EvalOptions {
            ignore_capacity,
            ..Default::default()
        };
        let inputs: Vec<_> = Strategy::sweep_bounded(1024, 1, 128)
            .unwrap()
            .iter()
            .map(|s| {
                derive_inputs(
                    &Transformer::t1().build(s).unwrap(),
                    &cluster,
                    &opts,
                )
                .unwrap()
            })
            .collect();
        let artifact = ev.evaluate(&inputs).unwrap();
        for (inp, a) in inputs.iter().zip(&artifact) {
            let n = comet::analytical::evaluate(inp);
            for (x, y) in a.as_array().iter().zip(n.as_array()) {
                // f32 vs f64; absolute slack for near-zero components.
                assert!(
                    (x - y).abs() <= 1e-4 * y.abs().max(1e-3),
                    "{} ({ignore_capacity}): artifact {x} native {y}",
                    inp.name
                );
            }
        }
    }
}

#[test]
fn artifact_matches_native_dlrm_and_variants() {
    let Some(rt) = runtime() else { return };
    let ev = BatchEvaluator::new(&rt);
    let d = Dlrm::dlrm_1_2t();
    let mut inputs = Vec::new();
    for n in [64usize, 32, 16, 8] {
        let w = d.build(n).unwrap();
        let mut cluster = presets::dgx_a100_64().with_n_nodes(n);
        cluster.node = cluster.node.with_expanded(300e9, 1e12);
        let opts = EvalOptions {
            footprint_override: Some(d.footprint_per_node(n)),
            ..Default::default()
        };
        inputs.push(derive_inputs(&w, &cluster, &opts).unwrap());
    }
    // Also every Table III cluster node definition on the 64-node DLRM.
    for cluster in presets::table3_all() {
        let n = 64.min(cluster.n_nodes);
        let sub = cluster.with_n_nodes(n);
        let w = d.build(n).unwrap();
        let opts = EvalOptions {
            footprint_override: Some(d.footprint_per_node(n)),
            ..Default::default()
        };
        inputs.push(derive_inputs(&w, &sub, &opts).unwrap());
    }
    let artifact = ev.evaluate(&inputs).unwrap();
    for (inp, a) in inputs.iter().zip(&artifact) {
        let n = comet::analytical::evaluate(inp);
        assert!(
            rel_diff(a.total(), n.total()) < 1e-4,
            "{}: artifact {} native {}",
            inp.name,
            a.total(),
            n.total()
        );
    }
}

#[test]
fn all_three_backends_rank_strategies_identically() {
    let native = Coordinator::native();
    let des = Coordinator::des();
    let artifact = Coordinator::artifact().ok();
    let cluster = presets::dgx_a100_1024();
    let opts = EvalOptions {
        ignore_capacity: true,
        ..Default::default()
    };
    let rank = |coord: &Coordinator| -> Vec<String> {
        let mut labeled: Vec<(String, f64)> =
            Strategy::sweep_bounded(1024, 1, 128)
                .unwrap()
                .iter()
                .map(|s| {
                    let w = Transformer::t1().build(s).unwrap();
                    let inp = derive_inputs(&w, &cluster, &opts).unwrap();
                    let t = coord
                        .evaluate_inputs(std::slice::from_ref(&inp))
                        .unwrap()[0]
                        .total();
                    (s.label(), t)
                })
                .collect();
        labeled.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        labeled.into_iter().map(|(l, _)| l).collect()
    };
    let rn = rank(&native);
    if let Some(artifact) = &artifact {
        assert_eq!(rn, rank(artifact), "artifact ranking diverged");
    } else if artifacts_required() {
        panic!("COMET_REQUIRE_ARTIFACTS=1 but artifact backend unavailable");
    } else {
        eprintln!("skipping artifact ranking (artifact backend unavailable)");
    }
    assert_eq!(rn, rank(&des), "DES ranking diverged");
    assert_eq!(rn[0], "MP8_DP128");
}

#[test]
fn batched_and_single_artifact_paths_agree() {
    let Some(rt) = runtime() else { return };
    let ev = BatchEvaluator::new(&rt);
    let cluster = presets::dgx_a100_1024();
    let opts = EvalOptions::default();
    let inputs: Vec<_> = Strategy::sweep_bounded(1024, 8, 128)
        .unwrap()
        .iter()
        .map(|s| {
            derive_inputs(
                &Transformer::t1().build(s).unwrap(),
                &cluster,
                &opts,
            )
            .unwrap()
        })
        .collect();
    let batched = ev.evaluate(&inputs).unwrap();
    for (inp, b) in inputs.iter().zip(&batched) {
        let single = ev.evaluate_one(inp).unwrap();
        assert!(
            rel_diff(single.total(), b.total()) < 1e-6,
            "{}",
            inp.name
        );
    }
}

#[test]
fn oversized_batches_chunk_correctly() {
    let Some(rt) = runtime() else { return };
    let ev = BatchEvaluator::new(&rt);
    let cluster = presets::dgx_a100_1024();
    let opts = EvalOptions::default();
    // 100 configs > the largest exported batch (64): forces chunking.
    let base = derive_inputs(
        &Transformer::t1()
            .build(&Strategy::new(8, 128).unwrap())
            .unwrap(),
        &cluster,
        &opts,
    )
    .unwrap();
    let inputs: Vec<_> = (0..100).map(|_| base.clone()).collect();
    let out = ev.evaluate(&inputs).unwrap();
    assert_eq!(out.len(), 100);
    let want = out[0].total();
    for b in &out {
        assert!(rel_diff(b.total(), want) < 1e-9);
    }
}
