//! CLI smoke tests: drive the `comet` binary end-to-end the way a user
//! would (figures, sweeps, config inspection, trace emission, validation).

use std::process::Command;

fn comet(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_comet"))
        .args(args)
        .output()
        .expect("spawn comet");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn figure_fig8a_prints_table() {
    let (ok, stdout, _) = comet(&["figure", "fig8a"]);
    assert!(ok);
    assert!(stdout.contains("MP8_DP128"));
    assert!(stdout.contains("FP_Exp_Comm"));
}

#[test]
fn figure_out_dir_writes_csv() {
    let dir = std::env::temp_dir().join("comet_cli_test_csv");
    let _ = std::fs::remove_dir_all(&dir);
    let (ok, _, _) =
        comet(&["figure", "fig6", "--out-dir", dir.to_str().unwrap()]);
    assert!(ok);
    let csv = std::fs::read_to_string(dir.join("fig6.csv")).unwrap();
    // The row label contains a comma, so the CSV writer quotes it.
    assert!(csv.starts_with("\"(MP, DP)\",baseline,zero-1,zero-2,zero-3"));
    assert_eq!(csv.lines().count(), 12);
}

#[test]
fn sweep_runs_on_preset() {
    let (ok, stdout, _) =
        comet(&["sweep", "--cluster", "B1", "--infinite-memory"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("MP8_DP128"));
    assert!(stdout.contains("footprint"));
}

#[test]
fn eval_single_config() {
    let (ok, stdout, _) = comet(&["eval", "--strategy", "MP64_DP16"]);
    assert!(ok);
    assert!(stdout.contains("total iteration time"));
}

#[test]
fn config_list_and_show() {
    let (ok, stdout, _) = comet(&["config", "list"]);
    assert!(ok);
    for name in ["A0", "B1", "C2", "TPUv4", "Dojo"] {
        assert!(stdout.contains(name), "{name} missing:\n{stdout}");
    }
    let (ok, stdout, _) = comet(&["config", "show", "B1"]);
    assert!(ok);
    assert!(stdout.contains("\"expanded_capacity\": 480000000000"));
}

#[test]
fn workload_emits_trace() {
    let (ok, stdout, _) = comet(&[
        "workload",
        "--model",
        "transformer-1t",
        "--strategy",
        "MP8_DP128",
    ]);
    assert!(ok);
    assert!(stdout.starts_with("# comet-workload v1"));
    assert!(stdout.contains("mlp-2"));
    // The emitted trace must parse back.
    comet::workload::trace::parse(&stdout).unwrap();
}

#[test]
fn unknown_args_fail_cleanly() {
    let (ok, _, stderr) = comet(&["figure", "fig99"]);
    assert!(!ok);
    assert!(stderr.contains("unknown figure"));
    let (ok, _, stderr) = comet(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("usage"));
    let (ok, _, stderr) = comet(&["sweep", "--cluster", "Z9"]);
    assert!(!ok);
    assert!(stderr.contains("unknown cluster"));
}

#[test]
fn validate_passes() {
    let (ok, stdout, stderr) = comet(&["validate"]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("validation OK"));
}
