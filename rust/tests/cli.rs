//! CLI smoke tests: drive the `comet` binary end-to-end the way a user
//! would (figures, sweeps, config inspection, trace emission, validation).

use std::process::Command;

fn comet(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_comet"))
        .args(args)
        .output()
        .expect("spawn comet");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn figure_fig8a_prints_table() {
    let (ok, stdout, _) = comet(&["figure", "fig8a"]);
    assert!(ok);
    assert!(stdout.contains("MP8_DP128"));
    assert!(stdout.contains("FP_Exp_Comm"));
}

#[test]
fn figure_out_dir_writes_csv() {
    let dir = std::env::temp_dir().join("comet_cli_test_csv");
    let _ = std::fs::remove_dir_all(&dir);
    let (ok, _, _) =
        comet(&["figure", "fig6", "--out-dir", dir.to_str().unwrap()]);
    assert!(ok);
    let csv = std::fs::read_to_string(dir.join("fig6.csv")).unwrap();
    // The row label contains a comma, so the CSV writer quotes it.
    assert!(csv.starts_with("\"(MP, DP)\",baseline,zero-1,zero-2,zero-3"));
    assert_eq!(csv.lines().count(), 12);
}

#[test]
fn sweep_runs_on_preset() {
    let (ok, stdout, _) =
        comet(&["sweep", "--cluster", "B1", "--infinite-memory"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("MP8_DP128"));
    assert!(stdout.contains("footprint"));
}

#[test]
fn eval_single_config() {
    let (ok, stdout, _) = comet(&["eval", "--strategy", "MP64_DP16"]);
    assert!(ok);
    assert!(stdout.contains("total iteration time"));
}

#[test]
fn config_list_and_show() {
    let (ok, stdout, _) = comet(&["config", "list"]);
    assert!(ok);
    for name in ["A0", "B1", "C2", "TPUv4", "Dojo"] {
        assert!(stdout.contains(name), "{name} missing:\n{stdout}");
    }
    let (ok, stdout, _) = comet(&["config", "show", "B1"]);
    assert!(ok);
    assert!(stdout.contains("\"expanded_capacity\": 480000000000"));
}

#[test]
fn workload_emits_trace() {
    let (ok, stdout, _) = comet(&[
        "workload",
        "--model",
        "transformer-1t",
        "--strategy",
        "MP8_DP128",
    ]);
    assert!(ok);
    assert!(stdout.starts_with("# comet-workload v1"));
    assert!(stdout.contains("mlp-2"));
    // The emitted trace must parse back.
    comet::workload::trace::parse(&stdout).unwrap();
}

#[test]
fn unknown_args_fail_cleanly() {
    let (ok, _, stderr) = comet(&["figure", "fig99"]);
    assert!(!ok);
    assert!(stderr.contains("unknown figure"));
    let (ok, _, stderr) = comet(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("usage"));
    let (ok, _, stderr) = comet(&["sweep", "--cluster", "Z9"]);
    assert!(!ok);
    assert!(stderr.contains("unknown cluster"));
}

#[test]
fn scenario_list_names_builtins() {
    let (ok, stdout, _) = comet(&["scenario", "list"]);
    assert!(ok);
    for name in ["quickstart", "fig8a", "fig15", "memory-expansion"] {
        assert!(stdout.contains(name), "{name} missing:\n{stdout}");
    }
}

#[test]
fn scenario_run_builtin_by_name() {
    let (ok, stdout, stderr) = comet(&["scenario", "run", "quickstart"]);
    assert!(ok, "stderr:\n{stderr}");
    assert!(stdout.contains("MP8_DP8"), "{stdout}");
    assert!(stdout.contains("Norm_to_best"));
}

#[test]
fn scenario_run_from_checked_in_file() {
    // Tests run with cwd = rust/; the spec fixtures live at the repo root.
    let (ok, stdout, stderr) =
        comet(&["scenario", "run", "../scenarios/quickstart.toml"]);
    assert!(ok, "stderr:\n{stderr}");
    assert!(stdout.contains("Quickstart"), "{stdout}");
}

#[test]
fn scenario_show_and_export_roundtrip() {
    let (ok, stdout, _) = comet(&["scenario", "show", "fig9"]);
    assert!(ok);
    assert!(stdout.contains("\"kind\": \"grid\""), "{stdout}");
    let (ok, stdout, _) = comet(&["scenario", "export", "fig9"]);
    assert!(ok);
    // The exported TOML must parse back to the same spec.
    let spec = comet::scenario::ScenarioSpec::parse_str(&stdout).unwrap();
    assert_eq!(spec, comet::scenario::registry::get("fig9").unwrap());
}

#[test]
fn scenario_errors_are_clean() {
    let (ok, _, stderr) = comet(&["scenario", "run", "no-such-scenario"]);
    assert!(!ok);
    assert!(stderr.contains("unknown scenario"), "{stderr}");
    let (ok, _, stderr) = comet(&["scenario", "frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("run|list|show|export"), "{stderr}");
}

#[test]
fn optimize_command_prints_topk_and_search_stats() {
    let (ok, stdout, stderr) = comet(&[
        "optimize",
        "--workload",
        "transformer-100m",
        "--cluster",
        "dgx-a100-64",
        "--max-mp",
        "8",
        "--top-k",
        "3",
        "--infinite-memory",
    ]);
    assert!(ok, "stderr:\n{stderr}");
    assert!(stdout.contains("Norm_to_best"), "{stdout}");
    assert!(stdout.contains("MP"), "{stdout}");
    assert!(stderr.contains("evaluated"), "{stderr}");
    assert!(stderr.contains("decompositions"), "{stderr}");
}

#[test]
fn optimize_command_rejects_bad_flags() {
    let (ok, _, stderr) = comet(&["optimize", "--workload", "resnet"]);
    assert!(!ok);
    assert!(stderr.contains("unknown workload"), "{stderr}");
    let (ok, _, stderr) =
        comet(&["optimize", "--em-bandwidths", "500,oops"]);
    assert!(!ok);
    assert!(stderr.contains("bad number"), "{stderr}");
    let (ok, _, stderr) =
        comet(&["optimize", "optimize-transformer", "--threads", "0"]);
    assert!(!ok);
    assert!(stderr.contains("threads"), "{stderr}");
}

#[test]
fn optimize_threads_output_is_byte_identical() {
    // The CI acceptance check, as a test: the same search at 1 and 4
    // evaluation lanes must print byte-identical JSON.
    let run = |threads: &str| {
        let (ok, stdout, stderr) = comet(&[
            "optimize",
            "optimize-transformer",
            "--threads",
            threads,
            "--json",
        ]);
        assert!(ok, "--threads {threads} stderr:\n{stderr}");
        assert!(stdout.contains("\"id\""), "{stdout}");
        stdout
    };
    let one = run("1");
    let four = run("4");
    assert_eq!(one, four, "thread count changed the optimize output");
    assert!(one.contains("MP8_DP128 EM@2039GB/s"), "{one}");
}

#[test]
fn json_flag_keeps_out_dir_artifacts() {
    // --json owns stdout but must not disable --out-dir persistence.
    let dir = std::env::temp_dir().join("comet_cli_json_outdir");
    let _ = std::fs::remove_dir_all(&dir);
    let (ok, stdout, stderr) = comet(&[
        "figure",
        "fig6",
        "--json",
        "--out-dir",
        dir.to_str().unwrap(),
    ]);
    assert!(ok, "stderr:\n{stderr}");
    assert!(stdout.trim_start().starts_with('{'), "{stdout}");
    assert!(dir.join("fig6.csv").exists(), "out-dir CSV must still land");
}

#[test]
fn scenario_run_accepts_multiple_targets_with_shared_coordinator() {
    // Two studies over the same workload in one invocation: the shared
    // derive cache means the second study re-uses the first's
    // decompositions (hits > 0 in the cumulative --verbose counters).
    let (ok, stdout, stderr) = comet(&[
        "scenario",
        "run",
        "optimize-transformer",
        "memory-expansion",
        "--verbose",
    ]);
    assert!(ok, "stderr:\n{stderr}");
    assert!(stdout.contains("MP8_DP128 EM@2039GB/s"), "{stdout}");
    assert!(stdout.contains("250GB/s"), "{stdout}");
    // Both studies reported against the same coordinator.
    assert!(
        stderr.contains("scenario 'optimize-transformer'"),
        "{stderr}"
    );
    assert!(stderr.contains("scenario 'memory-expansion'"), "{stderr}");
    let last = stderr
        .lines()
        .filter(|l| l.contains("derive cache"))
        .next_back()
        .unwrap();
    let hits: u64 = last
        .split_whitespace()
        .nth(3)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    assert!(hits > 0, "expected cross-study derive-cache hits: {last}");
}

#[test]
fn scenario_run_optimize_builtin_verbose_reports_search() {
    let (ok, stdout, stderr) = comet(&[
        "scenario",
        "run",
        "optimize-transformer",
        "--verbose",
    ]);
    assert!(ok, "stderr:\n{stderr}");
    assert!(stdout.contains("MP8_DP128 EM@2039GB/s"), "{stdout}");
    assert!(stdout.contains("pruned"), "{stdout}");
    assert!(stderr.contains("optimizer: evaluated"), "{stderr}");
    assert!(stderr.contains("derive cache"), "{stderr}");
}

#[test]
fn scenario_run_pipeline_builtin() {
    let (ok, stdout, stderr) =
        comet(&["scenario", "run", "pipeline-transformer"]);
    assert!(ok, "stderr:\n{stderr}");
    assert!(stdout.contains("PP8"), "{stdout}");
    assert!(stdout.contains("m=16"), "{stdout}");
    assert!(stdout.contains("gpipe"), "{stdout}");
}

#[test]
fn optimize_command_accepts_pipeline_scenario_target() {
    let (ok, stdout, stderr) =
        comet(&["optimize", "pipeline-transformer"]);
    assert!(ok, "stderr:\n{stderr}");
    // The argmin is a deep pipeline; starved shallow points are pruned
    // or infeasible.
    assert!(stdout.contains("PP8"), "{stdout}");
    assert!(stderr.contains("infeasible"), "{stderr}");
    // A non-searchable study is rejected loudly.
    let (ok, _, stderr) = comet(&["optimize", "fig8a"]);
    assert!(!ok);
    assert!(stderr.contains("optimize or pipeline"), "{stderr}");
}

#[test]
fn optimize_command_sweeps_the_pp_axis_from_flags() {
    let (ok, stdout, stderr) = comet(&[
        "optimize",
        "--workload",
        "transformer-100m",
        "--cluster",
        "dgx-a100-64",
        "--min-mp",
        "2",
        "--max-mp",
        "2",
        "--max-pp",
        "4",
        "--microbatches",
        "8",
        "--schedule",
        "1f1b",
        "--top-k",
        "3",
        "--infinite-memory",
    ]);
    assert!(ok, "stderr:\n{stderr}");
    assert!(stdout.contains("_PP"), "{stdout}");
}

#[test]
fn workload_trace_carries_pipeline_degree() {
    let (ok, stdout, _) = comet(&[
        "workload",
        "--model",
        "transformer-1t",
        "--strategy",
        "MP8_DP16_PP8",
    ]);
    assert!(ok);
    assert!(stdout.contains("pp=8"), "{}", stdout.lines().next().unwrap());
}

#[test]
fn optimize_goodput_objective_output_is_byte_identical_across_threads() {
    // The resilience acceptance check: the goodput-objective search must
    // also be thread-count invariant, byte for byte, on the JSON output.
    let run = |threads: &str| {
        let (ok, stdout, stderr) = comet(&[
            "optimize",
            "optimize-transformer",
            "--objective",
            "goodput",
            "--threads",
            threads,
            "--json",
        ]);
        assert!(ok, "--threads {threads} stderr:\n{stderr}");
        assert!(stdout.contains("\"id\""), "{stdout}");
        stdout
    };
    let one = run("1");
    let four = run("4");
    assert_eq!(one, four, "thread count changed the goodput output");
    // The goodput ranking reports effective seconds and efficiency.
    assert!(one.contains("Effective_s"), "{one}");
    assert!(one.contains("Efficiency"), "{one}");
}

#[test]
fn optimize_rejects_bad_objective() {
    let (ok, _, stderr) = comet(&[
        "optimize",
        "optimize-transformer",
        "--objective",
        "carbon",
    ]);
    assert!(!ok);
    assert!(stderr.contains("objective"), "{stderr}");
}

#[test]
fn scenario_run_resilience_builtin() {
    let (ok, stdout, stderr) =
        comet(&["scenario", "run", "resilience-transformer"]);
    assert!(ok, "stderr:\n{stderr}");
    assert!(stdout.contains("MTBF_500h"), "{stdout}");
    assert!(stdout.contains("best per MTBF"), "{stdout}");
}

#[test]
fn malformed_scenario_file_fails_cleanly_without_panic() {
    // A syntactically broken TOML must produce a one-line parse error
    // with a line number on stderr, a nonzero exit, and no panic spew.
    let dir = std::env::temp_dir().join("comet_cli_malformed");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("broken.toml");
    std::fs::write(&path, "name = \"broken\"\n[workload\nkind = 3\n")
        .unwrap();
    let (ok, _, stderr) = comet(&["scenario", "run", path.to_str().unwrap()]);
    assert!(!ok);
    assert!(stderr.contains("toml parse error"), "{stderr}");
    assert!(stderr.contains("line 2"), "{stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
    assert!(!stderr.contains("backtrace"), "{stderr}");
}

/// Like [`comet`], but returns the raw exit code and lets the caller set
/// environment variables on the child process only (never on the test
/// process — libtest runs tests concurrently in one process).
fn comet_code(
    args: &[&str],
    env: &[(&str, &str)],
) -> (Option<i32>, String, String) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_comet"));
    cmd.args(args);
    for (k, v) in env {
        cmd.env(k, v);
    }
    let out = cmd.output().expect("spawn comet");
    (
        out.status.code(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn exit_codes_distinguish_failure_classes() {
    // 0 = success.
    let (code, _, _) = comet_code(&["config", "list"], &[]);
    assert_eq!(code, Some(0));
    // 3 = configuration / input error.
    let (code, _, stderr) = comet_code(&["sweep", "--cluster", "Z9"], &[]);
    assert_eq!(code, Some(3), "{stderr}");
    let (code, _, stderr) =
        comet_code(&["optimize", "--deadline", "nope"], &[]);
    assert_eq!(code, Some(3), "{stderr}");
    assert!(stderr.contains("--deadline"), "{stderr}");
    let (code, _, stderr) = comet_code(
        &["optimize", "optimize-transformer", "--checkpoint-every", "5"],
        &[],
    );
    assert_eq!(code, Some(3), "{stderr}");
    assert!(stderr.contains("checkpoint"), "{stderr}");
}

#[test]
fn deadline_partial_checkpoint_then_resume_matches_uninterrupted() {
    // `--deadline 0` stops at the first safe boundary: exit 2 signals a
    // partial result and the checkpoint is flushed before exit.
    let dir = std::env::temp_dir().join("comet_cli_resume");
    let _ = std::fs::create_dir_all(&dir);
    let ck = dir.join("ck.json");
    let _ = std::fs::remove_file(&ck);
    let ck_s = ck.to_str().unwrap().to_owned();
    let (code, _, stderr) = comet_code(
        &[
            "optimize",
            "optimize-transformer",
            "--deadline",
            "0",
            "--checkpoint",
            &ck_s,
            "--json",
        ],
        &[],
    );
    assert_eq!(code, Some(2), "stderr:\n{stderr}");
    assert!(stderr.contains("PARTIAL"), "{stderr}");
    assert!(ck.exists(), "checkpoint must be flushed on deadline");
    // Resuming runs the search to completion, and the completed JSON is
    // byte-identical to a run that was never interrupted.
    let (code, resumed, stderr) = comet_code(
        &[
            "optimize",
            "optimize-transformer",
            "--resume",
            &ck_s,
            "--json",
        ],
        &[],
    );
    assert_eq!(code, Some(0), "stderr:\n{stderr}");
    let (code, oracle, stderr) =
        comet_code(&["optimize", "optimize-transformer", "--json"], &[]);
    assert_eq!(code, Some(0), "stderr:\n{stderr}");
    assert_eq!(resumed, oracle, "resume changed the optimize output");
    let _ = std::fs::remove_file(&ck);
}

#[test]
fn injected_worker_panic_is_isolated_and_exits_internal_error() {
    // COMET_PANIC_LEAF makes one lattice-point evaluation panic inside
    // the worker pool. The pool must capture it as a structured job
    // error — one clean line on stderr, exit code 4, no panic spew.
    // top_k covers the whole lattice so leaf 0 is always evaluated.
    let (code, _, stderr) = comet_code(
        &[
            "optimize",
            "--workload",
            "transformer-100m",
            "--cluster",
            "dgx-a100-64",
            "--max-mp",
            "8",
            "--top-k",
            "100",
            "--infinite-memory",
            "--threads",
            "2",
        ],
        &[("COMET_PANIC_LEAF", "0")],
    );
    assert_eq!(code, Some(4), "{stderr}");
    assert!(stderr.contains("job"), "{stderr}");
    assert!(stderr.contains("injected leaf panic"), "{stderr}");
    assert!(!stderr.contains("backtrace"), "{stderr}");
}

#[test]
fn validate_passes() {
    let (ok, stdout, stderr) = comet(&["validate"]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("validation OK"));
}
