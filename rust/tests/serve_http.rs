//! Socket-level integration tests for `comet serve`: a real child
//! process, real TCP connections, and the four robustness behaviors
//! the service guarantees — load-shedding, deadline partials, panic
//! isolation, and graceful drain — plus byte-identity between
//! `POST /run` bodies and `comet scenario run --json`.

#![cfg(unix)]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

extern "C" {
    fn kill(pid: i32, sig: i32) -> i32;
}

const SIGTERM: i32 = 15;

/// A running `comet serve` child bound to an ephemeral port. Dropping
/// it kills the process, so a failing assertion cannot leak servers.
struct ServerProc {
    child: Child,
    addr: SocketAddr,
}

impl ServerProc {
    fn spawn(extra: &[&str], envs: &[(&str, &str)]) -> ServerProc {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_comet"));
        cmd.arg("serve")
            .args(["--addr", "127.0.0.1:0"])
            .args(extra)
            .stdout(Stdio::piped())
            .stderr(Stdio::null());
        for (k, v) in envs {
            cmd.env(k, v);
        }
        let mut child = cmd.spawn().expect("spawn comet serve");
        // The first stdout line is `comet serve: listening on http://ADDR`.
        let stdout = child.stdout.take().expect("child stdout");
        let mut line = String::new();
        BufReader::new(stdout)
            .read_line(&mut line)
            .expect("read listen line");
        let addr = line
            .trim()
            .rsplit("http://")
            .next()
            .expect("listen line carries an address")
            .parse()
            .unwrap_or_else(|_| panic!("bad listen line: {line:?}"));
        ServerProc { child, addr }
    }

    fn sigterm(&self) {
        let rc = unsafe { kill(self.child.id() as i32, SIGTERM) };
        assert_eq!(rc, 0, "kill(SIGTERM) failed");
    }
}

impl Drop for ServerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// One full HTTP exchange: send `raw`, read the whole response (the
/// server closes every connection after one response).
fn http(addr: SocketAddr, raw: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    s.write_all(raw.as_bytes()).expect("send request");
    let mut out = String::new();
    s.read_to_string(&mut out).expect("read response");
    out
}

fn post_run(addr: SocketAddr, spec_json: &str, query: &str) -> String {
    http(
        addr,
        &format!(
            "POST /run{query} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            spec_json.len(),
            spec_json
        ),
    )
}

fn body_of(response: &str) -> &str {
    response.split("\r\n\r\n").nth(1).expect("response body")
}

/// Run the CLI and return stdout (panics on nonzero exit).
fn comet(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_comet"))
        .args(args)
        .output()
        .expect("run comet");
    assert!(
        out.status.success(),
        "comet {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf-8 stdout")
}

/// A numeric counter out of the `/stats` body without a JSON parser:
/// finds `"name": N` and parses N.
fn stat_counter(stats_body: &str, name: &str) -> f64 {
    let key = format!("\"{name}\":");
    let at = stats_body
        .find(&key)
        .unwrap_or_else(|| panic!("no {name} in stats: {stats_body}"));
    stats_body[at + key.len()..]
        .trim_start()
        .split(|c: char| c == ',' || c == '\n' || c == '}')
        .next()
        .unwrap()
        .trim()
        .parse()
        .unwrap_or_else(|_| panic!("bad {name} in stats: {stats_body}"))
}

#[test]
fn concurrent_clients_share_one_coordinator() {
    let srv = ServerProc::spawn(&[], &[]);
    let spec = comet(&["scenario", "show", "quickstart"]);
    // Four concurrent identical runs: all succeed, all byte-identical.
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let (addr, spec) = (srv.addr, spec.clone());
            std::thread::spawn(move || post_run(addr, &spec, ""))
        })
        .collect();
    let responses: Vec<String> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in &responses {
        assert!(r.starts_with("HTTP/1.1 200 OK\r\n"), "got: {r}");
        assert_eq!(body_of(r), body_of(&responses[0]));
    }
    // A further identical run must be a derive-cache hit: the four
    // runs above already populated the shared cache.
    let again = post_run(srv.addr, &spec, "");
    assert!(again.starts_with("HTTP/1.1 200 OK\r\n"));
    let stats = http(srv.addr, "GET /stats HTTP/1.1\r\n\r\n");
    let stats_body = body_of(&stats);
    assert!(
        stat_counter(stats_body, "hits") >= 1.0,
        "expected shared-cache hits after identical runs: {stats_body}"
    );
    assert!(stat_counter(stats_body, "completed") >= 5.0);
}

#[test]
fn run_bodies_are_byte_identical_to_cli_json() {
    let srv = ServerProc::spawn(&[], &[]);
    // One spec per study shape that the quick builtins cover; the CI
    // smoke step repeats the check end-to-end for the release binary.
    for name in ["quickstart", "memory-expansion", "tier-mapping"] {
        let spec = comet(&["scenario", "show", name]);
        let cli = comet(&["scenario", "run", name, "--json"]);
        let response = post_run(srv.addr, &spec, "");
        assert!(
            response.starts_with("HTTP/1.1 200 OK\r\n"),
            "{name}: {response}"
        );
        assert_eq!(
            body_of(&response),
            cli,
            "{name}: /run body must match `scenario run {name} --json`"
        );
    }
}

#[test]
fn past_deadline_requests_return_the_partial_shape() {
    let srv = ServerProc::spawn(&[], &[]);
    // Optimize study at deadline 0: 206 + the documented PARTIAL note,
    // best-so-far table still rendered.
    let spec = comet(&["scenario", "show", "optimize-transformer"]);
    let response = post_run(srv.addr, &spec, "?deadline_s=0");
    assert!(
        response.starts_with("HTTP/1.1 206 Partial Content\r\n"),
        "got: {response}"
    );
    assert!(
        body_of(&response).contains("PARTIAL (deadline)"),
        "206 body must carry the PARTIAL note: {response}"
    );
    // Non-optimize study at deadline 0: stopped at the first batch
    // boundary with the structured incomplete error.
    let grid = comet(&["scenario", "show", "quickstart"]);
    let stopped = post_run(srv.addr, &grid, "?deadline_s=0");
    assert!(
        stopped.starts_with("HTTP/1.1 504 Gateway Timeout\r\n"),
        "got: {stopped}"
    );
    let b = body_of(&stopped);
    assert!(b.contains("\"complete\":false"), "body: {b}");
    assert!(b.contains("\"kind\":\"deadline\""), "body: {b}");
    // The server is still healthy: the same spec completes unbounded.
    let fine = post_run(srv.addr, &grid, "");
    assert!(fine.starts_with("HTTP/1.1 200 OK\r\n"));
}

#[test]
fn a_panicked_request_is_isolated_from_its_neighbors() {
    // COMET_PANIC_LEAF trips a panic inside the first optimize leaf
    // evaluation — grid studies and the server itself are unaffected.
    let srv = ServerProc::spawn(&[], &[("COMET_PANIC_LEAF", "0")]);
    let poisoned = comet(&["scenario", "show", "optimize-transformer"]);
    let healthy = comet(&["scenario", "show", "quickstart"]);
    let (addr, spec) = (srv.addr, healthy.clone());
    let neighbor =
        std::thread::spawn(move || post_run(addr, &spec, ""));
    let response = post_run(srv.addr, &poisoned, "");
    assert!(
        response.starts_with("HTTP/1.1 500 Internal Server Error\r\n"),
        "got: {response}"
    );
    let b = body_of(&response);
    assert!(b.contains("\"kind\":\"panic\""), "body: {b}");
    assert!(b.contains("COMET_PANIC_LEAF"), "body: {b}");
    // The concurrent request and the server survive the panic.
    let ok = neighbor.join().unwrap();
    assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "got: {ok}");
    let health = http(srv.addr, "GET /healthz HTTP/1.1\r\n\r\n");
    assert!(health.starts_with("HTTP/1.1 200 OK\r\n"));
    let stats = http(srv.addr, "GET /stats HTTP/1.1\r\n\r\n");
    assert!(stat_counter(body_of(&stats), "panicked") >= 1.0);
    // And the pool still evaluates: run the healthy spec again.
    let again = post_run(srv.addr, &healthy, "");
    assert!(again.starts_with("HTTP/1.1 200 OK\r\n"));
}

#[test]
fn a_full_admission_queue_sheds_with_503_retry_after() {
    let srv = ServerProc::spawn(
        &["--max-queue", "1", "--max-concurrency", "1"],
        &[],
    );
    // Two stalled connections: the first occupies the only serving
    // worker (blocked reading its half-sent request), the second fills
    // the queue. Generous sleeps let the accept loop admit each one.
    let mut stall1 = TcpStream::connect(srv.addr).unwrap();
    stall1.write_all(b"GET /healthz HTTP/1.1\r\n").unwrap();
    std::thread::sleep(Duration::from_millis(300));
    let mut stall2 = TcpStream::connect(srv.addr).unwrap();
    stall2.write_all(b"GET /healthz HTTP/1.1\r\n").unwrap();
    std::thread::sleep(Duration::from_millis(300));
    // The third connection finds worker busy + queue full: shed.
    let shed = http(srv.addr, "GET /healthz HTTP/1.1\r\n\r\n");
    assert!(
        shed.starts_with("HTTP/1.1 503 Service Unavailable\r\n"),
        "got: {shed}"
    );
    assert!(shed.contains("Retry-After: 1\r\n"), "got: {shed}");
    assert!(body_of(&shed).contains("\"complete\":false"));
    // Release the stalled connections; service resumes untouched.
    stall1.write_all(b"\r\n").unwrap();
    stall2.write_all(b"\r\n").unwrap();
    let mut out = String::new();
    stall1.read_to_string(&mut out).unwrap();
    assert!(out.starts_with("HTTP/1.1 200 OK\r\n"), "got: {out}");
    let health = http(srv.addr, "GET /healthz HTTP/1.1\r\n\r\n");
    assert!(health.starts_with("HTTP/1.1 200 OK\r\n"));
    let stats = http(srv.addr, "GET /stats HTTP/1.1\r\n\r\n");
    assert!(stat_counter(body_of(&stats), "shed") >= 1.0);
}

#[test]
fn sigterm_drains_in_flight_work_and_exits_zero() {
    let mut srv = ServerProc::spawn(&[], &[]);
    // Prove liveness first so the signal race below is well-ordered.
    let health = http(srv.addr, "GET /healthz HTTP/1.1\r\n\r\n");
    assert!(health.starts_with("HTTP/1.1 200 OK\r\n"));
    let spec = comet(&["scenario", "show", "resilience-transformer"]);
    // Put a request in flight, then SIGTERM mid-execution: the drain
    // must deliver the full response before the process exits 0.
    let mut s = TcpStream::connect(srv.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    s.write_all(
        format!(
            "POST /run HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            spec.len(),
            spec
        )
        .as_bytes(),
    )
    .unwrap();
    std::thread::sleep(Duration::from_millis(200));
    srv.sigterm();
    let mut response = String::new();
    s.read_to_string(&mut response).unwrap();
    assert!(
        response.starts_with("HTTP/1.1 200 OK\r\n"),
        "in-flight request must finish through the drain: {response}"
    );
    let status = srv.child.wait().expect("wait for drained server");
    assert_eq!(status.code(), Some(0), "graceful drain must exit 0");
}
