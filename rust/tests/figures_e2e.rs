//! End-to-end figure regeneration with the paper's qualitative shapes
//! asserted — the executable form of EXPERIMENTS.md. Each test regenerates
//! one evaluation artifact of the paper and checks the claims its caption
//! and prose make.

use comet::coordinator::{sweep, Coordinator};

fn coord() -> Coordinator {
    Coordinator::native()
}

#[test]
fn fig6_footprint_claims() {
    let f = sweep::fig6();
    // Baseline grows exponentially as MP shrinks (16 psi / MP per node).
    let b_mp8 = f.cell("MP8_DP128", "baseline").unwrap();
    let b_mp1 = f.cell("MP1_DP1024", "baseline").unwrap();
    assert!((b_mp1 / b_mp8 - 8.0).abs() < 0.01);
    let b_mp64 = f.cell("MP64_DP16", "baseline").unwrap();
    assert!((b_mp8 / b_mp64 - 8.0).abs() < 0.01);
    // ZeRO-2 at MP8 still exceeds a single 80 GB device (paper: "the model
    // footprint per node eventually exceeds the typical memory capacity").
    assert!(f.cell("MP8_DP128", "zero-2").unwrap() > 80.0);
    // ZeRO-3 is the lowest at every MP degree.
    for (label, vals) in &f.rows {
        let z3 = f.cell(label, "zero-3").unwrap();
        assert!(vals.iter().all(|&v| v >= z3 - 1e-9), "{label}");
    }
}

#[test]
fn fig8a_claims() {
    let f = sweep::fig8a(&coord()).unwrap();
    // Headline: MP8_DP128 optimal.
    assert_eq!(f.argmin("Total_s"), Some("MP8_DP128"));
    // WG comm fully overlapped in every configuration.
    for (label, _) in &f.rows {
        assert_eq!(f.cell(label, "WG_Exp_Comm").unwrap(), 0.0, "{label}");
    }
    // Left of MP8: exposed FP comm grows with MP; right of MP8: compute
    // grows as MP shrinks.
    let fpx = |l: &str| f.cell(l, "FP_Exp_Comm").unwrap();
    assert!(fpx("MP64_DP16") > fpx("MP16_DP64"));
    assert!(fpx("MP16_DP64") > fpx("MP8_DP128"));
    let fpc = |l: &str| f.cell(l, "FP_Compute").unwrap();
    assert!(fpc("MP4_DP256") > fpc("MP8_DP128"));
    assert!(fpc("MP1_DP1024") > fpc("MP4_DP256"));
    // MP8 needs ~3.3x the 80 GB local memory; MP64 fits.
    let fp8 = f.cell("MP8_DP128", "Footprint_GB").unwrap();
    assert!((240.0..340.0).contains(&fp8), "{fp8}");
    assert!(f.cell("MP64_DP16", "Footprint_GB").unwrap() <= 80.0);
}

#[test]
fn fig8b_claims() {
    let f = sweep::fig8b(&coord()).unwrap();
    // Comm share dominates at high MP, becomes negligible from MP8 down.
    assert!(f.cell("MP64_DP16", "Exp_Comm_frac").unwrap() > 0.5);
    assert!(f.cell("MP8_DP128", "Exp_Comm_frac").unwrap() < 0.25);
    assert!(f.cell("MP2_DP512", "Exp_Comm_frac").unwrap() < 0.10);
}

#[test]
fn fig9_claims() {
    let f = sweep::fig9(&coord()).unwrap();
    // Configurations fitting in local memory are bandwidth-insensitive.
    let first = f.cell("MP64_DP16", "250GB/s").unwrap();
    let last = f.cell("MP64_DP16", "2039GB/s").unwrap();
    assert!((first - last).abs() < 1e-9);
    // Ex.1: MP8_DP128 beats the baseline once EM bandwidth is high enough,
    // with the crossover in the 250..1000 GB/s band (paper: ~500).
    assert!(f.cell("MP8_DP128", "250GB/s").unwrap() < 1.0);
    assert!(f.cell("MP8_DP128", "1000GB/s").unwrap() > 1.0);
    // Memory expansion never helps MP2 (strictly worse row).
    for col in &f.columns {
        assert!(f.cell("MP2_DP512", col).unwrap() < 1.0);
    }
    // Optimization opportunity magnitude ~1.2-1.4x (paper: up to 1.4x).
    let peak = f.cell("MP8_DP128", "2039GB/s").unwrap();
    assert!((1.1..1.5).contains(&peak), "{peak}");
}

#[test]
fn fig10_claims() {
    let f = sweep::fig10(&coord()).unwrap();
    let base = f.cell("compute x1", "EM@2039GB/s").unwrap();
    let half = f.cell("compute x0.5", "EM@2039GB/s").unwrap();
    let dbl = f.cell("compute x2", "EM@2039GB/s").unwrap();
    let quad = f.cell("compute x4", "EM@2039GB/s").unwrap();
    // Paper: halving compute => +50%; doubling => -25%; diminishing after.
    // Our calibration lands at +82% / -31% — same direction, steeper
    // because the MP8 workload is more compute-bound here (EXPERIMENTS.md).
    assert!((1.3..2.0).contains(&(half / base)), "half {}", half / base);
    assert!((0.55..0.9).contains(&(dbl / base)), "dbl {}", dbl / base);
    assert!(dbl - quad < base - dbl, "diminishing returns");
    // Lower EM bandwidth damps the impact of compute scaling.
    let gain_hi = f.cell("compute x0.5", "EM@2039GB/s").unwrap()
        - f.cell("compute x2", "EM@2039GB/s").unwrap();
    let gain_lo = f.cell("compute x0.5", "EM@500GB/s").unwrap()
        - f.cell("compute x2", "EM@500GB/s").unwrap();
    assert!(gain_lo < gain_hi);
}

#[test]
fn fig11_claims() {
    let f = sweep::fig11(&coord()).unwrap();
    // MP64: halving both bandwidths costs tens of percent; boosting both
    // amplifies beyond boosting one.
    let base = f.cell("MP64_DP16 intra x1", "inter x1").unwrap();
    assert!((base - 1.0).abs() < 1e-9);
    let both_half = f.cell("MP64_DP16 intra x0.5", "inter x0.5").unwrap();
    assert!(both_half < 0.80, "{both_half}");
    let only_intra = f.cell("MP64_DP16 intra x2", "inter x1").unwrap();
    let only_inter = f.cell("MP64_DP16 intra x1", "inter x2").unwrap();
    let both = f.cell("MP64_DP16 intra x2", "inter x2").unwrap();
    assert!(both > only_intra && both > only_inter, "amplificatory effect");
    // MP8: network-insensitive (halving both costs ~<15%).
    let mp8_half = f.cell("MP8_DP128 intra x0.5", "inter x0.5").unwrap();
    assert!(mp8_half > 0.85, "{mp8_half}");
    let mp8_4x = f.cell("MP8_DP128 intra x4", "inter x4").unwrap();
    assert!(mp8_4x < 1.15, "{mp8_4x}");
}

#[test]
fn fig12_claims() {
    let f = sweep::fig12(&coord()).unwrap();
    // MP64's optimum ratio lies in the paper's band (~1:6; we accept
    // 1:3..1:9.6) and beats the extremes.
    let best = f
        .rows
        .iter()
        .max_by(|a, b| a.1[0].partial_cmp(&b.1[0]).unwrap())
        .map(|(l, _)| l.clone())
        .unwrap();
    assert!(
        ["1:3", "1:4", "1:5", "1:6", "1:8"].contains(&best.as_str()),
        "best ratio {best}"
    );
    let best_v = f.cell(&best, "MP64_DP16").unwrap();
    assert!(best_v >= f.cell("1:1", "MP64_DP16").unwrap());
    assert!(best_v >= f.cell("1:24", "MP64_DP16").unwrap());
    // MP8 is largely insensitive until intra-pod bandwidth starves.
    let mp8_mid = f.cell("1:6", "MP8_DP128").unwrap();
    assert!((0.9..1.2).contains(&mp8_mid), "{mp8_mid}");
    let mp8_low = f.cell("1:1", "MP8_DP128").unwrap();
    assert!(mp8_low < mp8_mid, "intra starvation at 1:1");
}

#[test]
fn fig13_claims() {
    let fa = sweep::fig13a(&coord()).unwrap();
    // Sublinear growth in per-instance time as the cluster shrinks.
    let n32 = fa.cell("32 nodes", "Norm_to_64").unwrap();
    let n16 = fa.cell("16 nodes", "Norm_to_64").unwrap();
    let n8 = fa.cell("8 nodes", "Norm_to_64").unwrap();
    assert!(n32 > 1.0 && n32 < 2.0);
    assert!(n16 > n32 && n16 < 4.0);
    assert!(n8 < 8.0);
    // Exposed comm shrinks from 16 -> 8 nodes (single-pod all-to-all).
    let comm16 = fa.cell("16 nodes", "FP_Exp_Comm").unwrap();
    let comm8 = fa.cell("8 nodes", "FP_Exp_Comm").unwrap();
    assert!(comm8 < comm16);

    let fb = sweep::fig13b(&coord()).unwrap();
    // Paper: improvement needs ~>=75% extra capacity at >=800 GB/s; a
    // 200-ish GB expansion at 1.5 TB/s gives ~1.5x.
    assert!(fb.cell("16 nodes/instance", "500GB/s").unwrap() < 1.0);
    assert!(fb.cell("16 nodes/instance", "1250GB/s").unwrap() > 1.0);
    let v8 = fb.cell("8 nodes/instance", "1500GB/s").unwrap();
    assert!((1.3..2.3).contains(&v8), "{v8}");
    // DLRM is more memory-bandwidth-sensitive than the Transformer: the
    // 8-node packing's speedup must grow steeply with bandwidth.
    let lo = fb.cell("8 nodes/instance", "250GB/s").unwrap();
    let hi = fb.cell("8 nodes/instance", "2039GB/s").unwrap();
    assert!(hi / lo > 3.0);
}

#[test]
fn fig15_claims() {
    let f = sweep::fig15(&coord()).unwrap();
    let t = |c: &str| f.cell(c, "Transformer-1T").unwrap();
    let d = |c: &str| f.cell(c, "DLRM_x8").unwrap();
    // Transformer: memory expansion helps every cluster family.
    assert!(t("A1") > t("A0"));
    assert!(t("B1") > t("B0"));
    assert!(t("C1") > t("C0"));
    assert!(t("C2") > t("C1"));
    // DLRM: expansion helps only the lowest-end (A) family on balance.
    assert!(d("A1") > d("A0"));
    assert!(d("A2") > d("A1"));
    assert!(d("B1") < d("B0"));
    assert!(d("C1") < d("C0"));
    // C-family is the best GPU cluster; headline magnitude band around the
    // paper's 7.7x.
    let c0_avg = (t("C0") * d("C0")).sqrt();
    assert!((4.0..13.0).contains(&c0_avg), "C0 avg {c0_avg}");
    // Dojo leads both workloads (huge SRAM + memory + network).
    for name in ["A0", "A1", "A2", "B0", "B1", "B2", "C0", "C1", "C2", "TPUv4"]
    {
        assert!(t("Dojo") > t(name));
        assert!(d("Dojo") > d(name));
    }
    // TPU: strong for Transformer, DLRM capped by memory capacity.
    assert!(t("TPUv4") > t("B2"));
    assert!(d("TPUv4") < d("B2") * 2.0);
}

#[test]
fn all_figures_regenerate_quickly() {
    let t0 = std::time::Instant::now();
    let figs = sweep::all_figures(&coord()).unwrap();
    assert_eq!(figs.len(), 10);
    // The paper's SV-E: hours per heatmap. Ours: the whole set in < 60 s
    // even on a cold cache and debug-adjacent settings.
    assert!(
        t0.elapsed().as_secs() < 60,
        "{:?} is too slow",
        t0.elapsed()
    );
    for f in &figs {
        assert!(!f.rows.is_empty(), "{} empty", f.id);
        let csv = f.to_csv();
        assert!(csv.lines().count() == f.rows.len() + 1, "{} csv", f.id);
        assert!(!f.to_table().is_empty());
    }
}

#[test]
fn ablation_claims() {
    let c = coord();
    // Collectives ablation: hierarchical collectives collapse the
    // pod-straddling penalty (>2x cheaper at MP>=16), and leave intra-pod
    // configurations untouched — i.e. Fig. 8's MP8 optimum is a
    // topology-awareness effect of Table I's logical-ring collectives.
    let f = sweep::ablation_collectives(&c).unwrap();
    assert!(f.cell("MP64_DP16", "ring/hier").unwrap() > 2.0);
    assert!((f.cell("MP8_DP128", "ring/hier").unwrap() - 1.0).abs() < 1e-9);
    for (label, _) in &f.rows {
        assert!(f.cell(label, "ring/hier").unwrap() >= 1.0 - 1e-9, "{label}");
    }

    // ZeRO ablation: stage 3 cuts MP8's footprint ~15x below stage 2 and
    // its 1.5x DP volume still hides under WG compute on this balance.
    let f = sweep::ablation_zero(&c).unwrap();
    let z2 = f.cell("MP8_DP128 zero-2", "Footprint_GB").unwrap();
    let z3 = f.cell("MP8_DP128 zero-3", "Footprint_GB").unwrap();
    assert!(z2 / z3 > 10.0);
    assert_eq!(f.cell("MP8_DP128 zero-3", "WG_Exp_Comm_s").unwrap(), 0.0);
}
