//! Scenario-engine integration tests: built-in registry specs must
//! reproduce the legacy hand-written figure drivers cell-for-cell, specs
//! must round-trip through JSON text and TOML export, and malformed
//! specs must fail loudly.

use comet::config::presets;
use comet::coordinator::{sweep, Coordinator};
use comet::model::inputs::{
    decompose, derive_inputs, resolve_inputs, EvalOptions,
};
use comet::network::CollectiveImpl;
use comet::parallel::{footprint_per_node, Strategy, ZeroStage};
use comet::report::FigureData;
use comet::scenario::{optimizer_for, registry, run, ScenarioSpec};
use comet::util::json;
use comet::util::units::gb;
use comet::workload::dlrm::Dlrm;
use comet::workload::transformer::Transformer;

/// Full structural + bit-exact numeric equality (NaN == NaN: the same
/// code path must produce the same bits).
fn assert_figures_eq(got: &FigureData, want: &FigureData) {
    assert_eq!(got.id, want.id);
    assert_eq!(got.title, want.title);
    assert_eq!(got.row_label, want.row_label);
    assert_eq!(got.columns, want.columns, "{}", got.id);
    assert_eq!(got.notes, want.notes, "{}", got.id);
    assert_eq!(got.rows.len(), want.rows.len(), "{}", got.id);
    for ((gl, gv), (wl, wv)) in got.rows.iter().zip(&want.rows) {
        assert_eq!(gl, wl, "{}", got.id);
        assert_eq!(gv.len(), wv.len(), "{}/{}", got.id, gl);
        for (i, (g, w)) in gv.iter().zip(wv).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "{}/{}[{i}]: {g} != {w}",
                got.id,
                gl
            );
        }
    }
}

fn run_builtin(name: &str, coord: &Coordinator) -> FigureData {
    let spec = registry::get(name).unwrap();
    run(&spec, coord).unwrap_or_else(|e| panic!("{name}: {e}"))
}

// ---- registry vs legacy drivers (the acceptance-criterion trio first) -----

#[test]
fn fig8a_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig8a", &coord), &sweep::fig8a(&coord).unwrap());
}

#[test]
fn fig11_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig11", &coord), &sweep::fig11(&coord).unwrap());
}

#[test]
fn fig13a_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig13a", &coord), &sweep::fig13a(&coord).unwrap());
}

#[test]
fn fig6_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig6", &coord), &sweep::fig6());
}

#[test]
fn fig8b_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig8b", &coord), &sweep::fig8b(&coord).unwrap());
}

#[test]
fn fig9_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig9", &coord), &sweep::fig9(&coord).unwrap());
}

#[test]
fn fig10_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig10", &coord), &sweep::fig10(&coord).unwrap());
}

#[test]
fn fig12_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig12", &coord), &sweep::fig12(&coord).unwrap());
}

#[test]
fn fig13b_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig13b", &coord), &sweep::fig13b(&coord).unwrap());
}

#[test]
fn fig15_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig15", &coord), &sweep::fig15(&coord).unwrap());
}

#[test]
fn ablation_collectives_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(
        &run_builtin("ablation-collectives", &coord),
        &sweep::ablation_collectives(&coord).unwrap(),
    );
}

#[test]
fn ablation_zero_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(
        &run_builtin("ablation-zero", &coord),
        &sweep::ablation_zero(&coord).unwrap(),
    );
}

// ---- two-stage derive vs single-pass oracle -------------------------------

/// The two-stage derive (decompose + resolve, the batched hot path) must
/// produce bit-identical `ModelInputs` to the single-pass `derive_inputs`
/// oracle across the design spaces of all 12 built-in figure scenarios:
/// the Fig. 8/9 strategy x memory grids, Fig. 10's scaled-compute nodes,
/// Fig. 11/12's scaled and rebalanced networks, Fig. 13's DLRM sizings
/// with footprint overrides, and Fig. 15's Table III clusters.
#[test]
fn two_stage_derive_matches_single_pass_across_figure_spaces() {
    let base = presets::dgx_a100_1024();
    let infinite = EvalOptions {
        ignore_capacity: true,
        ..Default::default()
    };
    let hier_infinite = EvalOptions {
        collective_impl: CollectiveImpl::Hierarchical,
        ..infinite
    };
    let mut specs: Vec<(
        comet::workload::Workload,
        comet::ClusterConfig,
        EvalOptions,
    )> = Vec::new();

    // Figs. 8a/8b + ablation-collectives + ablation-zero: the full
    // strategy sweep under both collectives and every ZeRO stage.
    for s in Strategy::sweep_bounded(1024, 1, 128).unwrap() {
        let w = Transformer::t1().build(&s).unwrap();
        specs.push((w.clone(), base.clone(), infinite));
        specs.push((w.clone(), base.clone(), hier_infinite));
        for stage in ZeroStage::ALL {
            specs.push((
                w.clone(),
                base.clone(),
                EvalOptions {
                    zero_stage: stage,
                    ..infinite
                },
            ));
        }
    }
    // Fig. 9 + memory-expansion: spill-sized expanded memory per point.
    for s in Strategy::sweep_bounded(1024, 2, 128).unwrap() {
        let w = Transformer::t1().build(&s).unwrap();
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG).total();
        let need = (fp - base.node.local.capacity).max(0.0);
        for bw in [250.0, 1000.0, 2039.0] {
            let cluster = if need > 0.0 {
                base.with_node(base.node.with_expanded(need, gb(bw)))
            } else {
                base.clone()
            };
            specs.push((w.clone(), cluster, EvalOptions::default()));
        }
    }
    // Fig. 10: compute-capability scaling.
    {
        let s = Strategy::new(8, 128).unwrap();
        let w = Transformer::t1().build(&s).unwrap();
        let fp = footprint_per_node(&w, &s, ZeroStage::OsG).total();
        let need = (fp - base.node.local.capacity).max(0.0);
        for sc in [0.25, 1.0, 8.0] {
            let node = base.node.scale_compute(sc).with_expanded(need, gb(1000.0));
            specs.push((w.clone(), base.with_node(node), EvalOptions::default()));
        }
    }
    // Figs. 11/12: scaled and rebalanced networks.
    for s in [
        Strategy::new(64, 16).unwrap(),
        Strategy::new(8, 128).unwrap(),
    ] {
        let w = Transformer::t1().build(&s).unwrap();
        specs.push((w.clone(), base.scale_network(2.0, 0.5), hier_infinite));
        specs.push((
            w.clone(),
            base.rebalance_network(6.0).unwrap(),
            hier_infinite,
        ));
    }
    // Figs. 13a/13b: DLRM sizings with footprint overrides + EM.
    let d = Dlrm::dlrm_1_2t();
    for n in [64usize, 32, 16, 8] {
        let w = d.build(n).unwrap();
        let fp = d.footprint_per_node(n);
        let opts = EvalOptions {
            footprint_override: Some(fp),
            ..Default::default()
        };
        let mut cluster = presets::dgx_a100_64().with_n_nodes(n);
        let need = (fp - cluster.node.local.capacity).max(0.0);
        if need > 0.0 {
            cluster.node = cluster.node.with_expanded(need, 2e12);
        }
        specs.push((w, cluster, opts));
    }
    // Fig. 15 / cluster-compare: every Table III cluster, DLRM packing +
    // a feasible transformer strategy.
    for cluster in presets::table3_all() {
        let n_i = 8.min(cluster.n_nodes);
        specs.push((
            d.build(n_i).unwrap(),
            cluster.with_n_nodes(n_i),
            EvalOptions {
                footprint_override: Some(d.footprint_per_node(n_i)),
                ..Default::default()
            },
        ));
        let s = Strategy::new(
            64.min(cluster.n_nodes),
            cluster.n_nodes / 64.min(cluster.n_nodes),
        )
        .unwrap();
        specs.push((
            Transformer::t1().build(&s).unwrap(),
            cluster.clone(),
            EvalOptions::default(),
        ));
    }

    assert!(specs.len() > 100, "space under-covered: {}", specs.len());
    for (i, (w, c, o)) in specs.iter().enumerate() {
        let single = derive_inputs(w, c, o).unwrap();
        let staged = resolve_inputs(&decompose(w), c, o).unwrap();
        assert_eq!(single, staged, "spec {i} ({})", single.name);
        assert_eq!(
            single.fingerprint(),
            staged.fingerprint(),
            "spec {i} ({})",
            single.name
        );
    }
}

// ---- optimize built-ins ---------------------------------------------------

/// Acceptance criterion: on both built-in optimize scenarios the
/// branch-and-bound search evaluates at most half of the exhaustive
/// grid's points while returning the identical argmin and top-k.
#[test]
fn optimize_builtins_prune_half_and_match_exhaustive() {
    for name in ["optimize-transformer", "optimize-dlrm"] {
        let spec = registry::get(name).unwrap();
        let coord = Coordinator::native();
        let opt = optimizer_for(&spec, &coord).unwrap();
        let s = opt.search().unwrap();
        let e = opt.exhaustive().unwrap();
        assert_eq!(s.top.len(), e.top.len(), "{name}");
        for (a, b) in s.top.iter().zip(&e.top) {
            assert_eq!(a.point.index, b.point.index, "{name}");
            assert_eq!(a.label, b.label, "{name}");
            assert_eq!(a.total().to_bits(), b.total().to_bits(), "{name}");
        }
        assert!(
            2 * s.evaluated <= e.evaluated,
            "{name}: search evaluated {} of {} exhaustive points (> 50%)",
            s.evaluated,
            e.evaluated
        );
        assert_eq!(s.evaluated + s.pruned, e.evaluated, "{name}");
        // Thread invariance on the shipped scenarios: the parallel
        // driver's Outcome is bit-identical to the sequential oracle
        // (shared checker — same strictness everywhere).
        let seq = opt.search_sequential().unwrap();
        for lanes in [2usize, 4] {
            let par = opt.search_parallel(lanes).unwrap();
            seq.assert_bit_identical(&par, &format!("{name} t{lanes}"));
        }
    }
}

#[test]
fn optimize_transformer_finds_the_paper_co_design() {
    // Paper Ex. 1 / Fig. 9: with full-rate expanded memory, MP8_DP128
    // overtakes every feasible local-memory configuration.
    let coord = Coordinator::native();
    let spec = registry::get("optimize-transformer").unwrap();
    let out = optimizer_for(&spec, &coord).unwrap().search().unwrap();
    let best = out.best().unwrap();
    assert_eq!(best.label, "MP8_DP128 EM@2039GB/s");
    assert_eq!(out.top.len(), 5);
    assert_eq!(out.total_points, 49);
    assert_eq!(out.infeasible, 0);
}

#[test]
fn optimize_dlrm_prunes_infeasible_capacity_column() {
    let coord = Coordinator::native();
    let spec = registry::get("optimize-dlrm").unwrap();
    let out = optimizer_for(&spec, &coord).unwrap().search().unwrap();
    // 7 bandwidths x 3 capacities x 2 collectives; the 40 GB column
    // (14 points) cannot hold the 70 GB spill.
    assert_eq!(out.total_points, 42);
    assert_eq!(out.infeasible, 14);
    let best = out.best().unwrap();
    assert!(best.label.contains("EM@2039GB/s"), "{}", best.label);
    assert!(best.footprint > 80e9);
}

#[test]
fn optimize_builtins_render_through_scenario_run() {
    let coord = Coordinator::native();
    for name in ["optimize-transformer", "optimize-dlrm"] {
        let fig = run(&registry::get(name).unwrap(), &coord)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(fig.rows.len(), 5, "{name}");
        assert!(fig.columns.contains(&"Pareto".into()), "{name}");
        assert!(
            fig.notes.iter().any(|n| n.contains("pruned")),
            "{name}: {:?}",
            fig.notes
        );
    }
}

// ---- pipeline builtin -----------------------------------------------------

/// Acceptance criterion: the `pipeline-transformer` builtin runs through
/// the scenario engine (the PP x microbatch x schedule grid) AND through
/// the branch-and-bound optimizer (`comet optimize pipeline-transformer`
/// drives the same path), with search == exhaustive on the 3D lattice.
#[test]
fn pipeline_transformer_runs_via_scenario_and_optimizer() {
    let coord = Coordinator::native();
    let spec = registry::get("pipeline-transformer").unwrap();

    // Scenario-run path: 1 PP1 row + 3 PP-planes x 2 schedules.
    let fig = run(&spec, &coord).unwrap();
    assert_eq!(fig.rows.len(), 1 + 3 * 2);
    assert_eq!(fig.columns, vec!["m=4", "m=8", "m=16"]);
    // PP1 = MP8_DP128 starves its 264 GB footprint without expansion;
    // the pipeline rows run at full local bandwidth.
    let pp1 = fig.cell("PP1", "m=8").unwrap();
    let pp8 = fig.cell("PP8 1f1b", "m=16").unwrap();
    assert!(pp1 > 100.0 * pp8, "PP1 {pp1} vs PP8 {pp8}");

    // Optimizer path: same lattice as branches; exact search.
    let opt = optimizer_for(&spec, &coord).unwrap();
    let s = opt.search().unwrap();
    let e = opt.exhaustive().unwrap();
    // 1 deduped PP1 branch + 3 PP planes x 2 schedules x 3 microbatches.
    assert_eq!(s.total_points, 1 + 3 * 2 * 3);
    assert_eq!(s.infeasible, e.infeasible);
    // The starved PP1 point exceeds the 80 GB node with no expansion
    // axis: capacity-infeasible, pruned unevaluated (PP2 spills too).
    assert!(s.infeasible >= 1, "{}", s.infeasible);
    let best = s.best().unwrap();
    assert_eq!(best.label, e.best().unwrap().label);
    assert_eq!(
        best.total().to_bits(),
        e.best().unwrap().total().to_bits()
    );
    // The argmin is a deep pipeline at the largest microbatch count.
    assert!(best.label.contains("PP8"), "{}", best.label);
    assert!(best.label.contains("m16"), "{}", best.label);
    assert!(best.footprint <= 80e9, "argmin must fit: {}", best.footprint);
    assert!(best.breakdown.bubble > 0.0);
}

// ---- tiered builtins ------------------------------------------------------

/// The two tiered-cluster builtins run end-to-end: `tier-mapping`
/// produces the full strategy x mapping grid with finite positive cells,
/// and `optimize-tiered` returns the exhaustive top-k bit-for-bit on the
/// heterogeneous 3-tier lattice.
#[test]
fn tiered_builtins_run_through_scenario_engine() {
    let coord = Coordinator::native();
    let fig = run(&registry::get("tier-mapping").unwrap(), &coord).unwrap();
    assert_eq!(fig.rows.len(), 4);
    assert_eq!(fig.columns, vec!["mp-inner", "dp-inner"]);
    for r in ["MP8_DP8", "MP4_DP16", "MP16_DP4", "MP2_DP32"] {
        for c in ["mp-inner", "dp-inner"] {
            let v = fig.cell(r, c).unwrap();
            assert!(v.is_finite() && v > 0.0, "{r}/{c}: {v}");
        }
    }

    let spec = registry::get("optimize-tiered").unwrap();
    let opt = optimizer_for(&spec, &coord).unwrap();
    let s = opt.search().unwrap();
    let e = opt.exhaustive().unwrap();
    assert_eq!(s.top.len(), e.top.len());
    for (a, b) in s.top.iter().zip(&e.top) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.point.index, b.point.index);
        assert_eq!(a.total().to_bits(), b.total().to_bits());
    }
    assert_eq!(s.infeasible, e.infeasible);
    assert_eq!(s.evaluated + s.pruned, e.evaluated);
}

// ---- spec round-trips -----------------------------------------------------

#[test]
fn every_builtin_roundtrips_through_json_text() {
    for name in registry::names() {
        let spec = registry::get(name).unwrap();
        let text = spec.to_json().to_string_pretty();
        let back = ScenarioSpec::from_json(&json::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(spec, back, "{name}");
    }
}

#[test]
fn every_builtin_roundtrips_through_toml_export() {
    for name in registry::names() {
        let spec = registry::get(name).unwrap();
        let toml = spec.to_toml().unwrap_or_else(|e| panic!("{name}: {e}"));
        let back = ScenarioSpec::parse_str(&toml)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(spec, back, "{name}");
    }
}

// ---- sanity on case studies ----------------------------------------------

#[test]
fn memory_expansion_crosses_over() {
    // The case study's headline: MP8_DP128 loses at 250 GB/s EM, wins by
    // ~1.4x at full-rate EM (paper Ex. 1).
    let coord = Coordinator::native();
    let f = run_builtin("memory-expansion", &coord);
    let lo = f.cell("MP8_DP128", "250GB/s").unwrap();
    let hi = f.cell("MP8_DP128", "2039GB/s").unwrap();
    assert!(lo < 1.0, "{lo}");
    assert!(hi > 1.0 && hi < 2.5, "{hi}");
}

#[test]
fn cluster_compare_case_study_mirrors_fig15_values() {
    let coord = Coordinator::native();
    let case = run_builtin("cluster-compare", &coord);
    let fig15 = sweep::fig15(&coord).unwrap();
    // Same engine, same numbers; only id/title differ.
    for (row, want) in case.rows.iter().zip(&fig15.rows) {
        assert_eq!(row.0, want.0);
        for (g, w) in row.1.iter().zip(&want.1) {
            assert_eq!(g.to_bits(), w.to_bits(), "{}", row.0);
        }
    }
    let c = case.cell("C2", "DLRM_x8").unwrap();
    assert!(c > 2.0, "C2 DLRM speedup {c}");
}

#[test]
fn quickstart_and_gemm_builtins_run() {
    let coord = Coordinator::native();
    let q = run_builtin("quickstart", &coord);
    assert_eq!(q.rows.len(), 4);
    let g = run_builtin("gemm-roofline", &coord);
    assert_eq!(g.rows.len(), 4);
    assert!(g.cell("MP1_DP512", "Total_s").unwrap() > 0.0);
}

// ---- error paths ----------------------------------------------------------

#[test]
fn malformed_specs_fail_loudly() {
    // TOML syntax error.
    assert!(ScenarioSpec::parse_str("name = \n").is_err());
    // Unknown study kind.
    assert!(ScenarioSpec::parse_str(
        "name = \"x\"\n[study]\nkind = \"frobnicate\"\n"
    )
    .is_err());
    // Unknown key (typo'd axis name).
    assert!(ScenarioSpec::parse_str(
        "name = \"x\"\n[study]\nkind = \"grid\"\nem_bandwidth_gbps = [1]\n"
    )
    .is_err());
    // Strategy label garbage.
    assert!(ScenarioSpec::parse_str(
        "name = \"x\"\n[study]\nkind = \"grid\"\nstrategies = [\"8x128\"]\n"
    )
    .is_err());
    // Cluster that fails validation (non-power-of-two).
    assert!(ScenarioSpec::parse_str(
        "name = \"x\"\n[cluster]\npreset = \"baseline\"\nn_nodes = 1000\n\
         [study]\nkind = \"grid\"\n"
    )
    .is_err());
}

#[test]
fn run_rejects_inconsistent_specs() {
    let coord = Coordinator::native();
    // Speedup without a baseline.
    let spec = ScenarioSpec::parse_str(
        "name = \"x\"\n[study]\nkind = \"grid\"\n\
         strategies = [\"MP8_DP128\"]\nem_bandwidths_gbps = [500]\n\
         [output]\ncontent = \"speedup\"\n",
    )
    .unwrap();
    assert!(run(&spec, &coord).is_err());
    // DLRM study with a transformer workload.
    let spec = ScenarioSpec::parse_str(
        "name = \"x\"\n[study]\nkind = \"packing\"\npackings = [8]\n\
         em_bandwidths_gbps = [500]\n",
    )
    .unwrap();
    assert!(run(&spec, &coord).is_err());
}
