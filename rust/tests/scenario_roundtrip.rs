//! Scenario-engine integration tests: built-in registry specs must
//! reproduce the legacy hand-written figure drivers cell-for-cell, specs
//! must round-trip through JSON text and TOML export, and malformed
//! specs must fail loudly.

use comet::coordinator::{sweep, Coordinator};
use comet::report::FigureData;
use comet::scenario::{registry, run, ScenarioSpec};
use comet::util::json;

/// Full structural + bit-exact numeric equality (NaN == NaN: the same
/// code path must produce the same bits).
fn assert_figures_eq(got: &FigureData, want: &FigureData) {
    assert_eq!(got.id, want.id);
    assert_eq!(got.title, want.title);
    assert_eq!(got.row_label, want.row_label);
    assert_eq!(got.columns, want.columns, "{}", got.id);
    assert_eq!(got.notes, want.notes, "{}", got.id);
    assert_eq!(got.rows.len(), want.rows.len(), "{}", got.id);
    for ((gl, gv), (wl, wv)) in got.rows.iter().zip(&want.rows) {
        assert_eq!(gl, wl, "{}", got.id);
        assert_eq!(gv.len(), wv.len(), "{}/{}", got.id, gl);
        for (i, (g, w)) in gv.iter().zip(wv).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "{}/{}[{i}]: {g} != {w}",
                got.id,
                gl
            );
        }
    }
}

fn run_builtin(name: &str, coord: &Coordinator) -> FigureData {
    let spec = registry::get(name).unwrap();
    run(&spec, coord).unwrap_or_else(|e| panic!("{name}: {e}"))
}

// ---- registry vs legacy drivers (the acceptance-criterion trio first) -----

#[test]
fn fig8a_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig8a", &coord), &sweep::fig8a(&coord).unwrap());
}

#[test]
fn fig11_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig11", &coord), &sweep::fig11(&coord).unwrap());
}

#[test]
fn fig13a_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig13a", &coord), &sweep::fig13a(&coord).unwrap());
}

#[test]
fn fig6_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig6", &coord), &sweep::fig6());
}

#[test]
fn fig8b_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig8b", &coord), &sweep::fig8b(&coord).unwrap());
}

#[test]
fn fig9_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig9", &coord), &sweep::fig9(&coord).unwrap());
}

#[test]
fn fig10_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig10", &coord), &sweep::fig10(&coord).unwrap());
}

#[test]
fn fig12_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig12", &coord), &sweep::fig12(&coord).unwrap());
}

#[test]
fn fig13b_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig13b", &coord), &sweep::fig13b(&coord).unwrap());
}

#[test]
fn fig15_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(&run_builtin("fig15", &coord), &sweep::fig15(&coord).unwrap());
}

#[test]
fn ablation_collectives_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(
        &run_builtin("ablation-collectives", &coord),
        &sweep::ablation_collectives(&coord).unwrap(),
    );
}

#[test]
fn ablation_zero_matches_legacy() {
    let coord = Coordinator::native();
    assert_figures_eq(
        &run_builtin("ablation-zero", &coord),
        &sweep::ablation_zero(&coord).unwrap(),
    );
}

// ---- spec round-trips -----------------------------------------------------

#[test]
fn every_builtin_roundtrips_through_json_text() {
    for name in registry::names() {
        let spec = registry::get(name).unwrap();
        let text = spec.to_json().to_string_pretty();
        let back = ScenarioSpec::from_json(&json::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(spec, back, "{name}");
    }
}

#[test]
fn every_builtin_roundtrips_through_toml_export() {
    for name in registry::names() {
        let spec = registry::get(name).unwrap();
        let toml = spec.to_toml().unwrap_or_else(|e| panic!("{name}: {e}"));
        let back = ScenarioSpec::parse_str(&toml)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(spec, back, "{name}");
    }
}

// ---- sanity on case studies ----------------------------------------------

#[test]
fn memory_expansion_crosses_over() {
    // The case study's headline: MP8_DP128 loses at 250 GB/s EM, wins by
    // ~1.4x at full-rate EM (paper Ex. 1).
    let coord = Coordinator::native();
    let f = run_builtin("memory-expansion", &coord);
    let lo = f.cell("MP8_DP128", "250GB/s").unwrap();
    let hi = f.cell("MP8_DP128", "2039GB/s").unwrap();
    assert!(lo < 1.0, "{lo}");
    assert!(hi > 1.0 && hi < 2.5, "{hi}");
}

#[test]
fn cluster_compare_case_study_mirrors_fig15_values() {
    let coord = Coordinator::native();
    let case = run_builtin("cluster-compare", &coord);
    let fig15 = sweep::fig15(&coord).unwrap();
    // Same engine, same numbers; only id/title differ.
    for (row, want) in case.rows.iter().zip(&fig15.rows) {
        assert_eq!(row.0, want.0);
        for (g, w) in row.1.iter().zip(&want.1) {
            assert_eq!(g.to_bits(), w.to_bits(), "{}", row.0);
        }
    }
    let c = case.cell("C2", "DLRM_x8").unwrap();
    assert!(c > 2.0, "C2 DLRM speedup {c}");
}

#[test]
fn quickstart_and_gemm_builtins_run() {
    let coord = Coordinator::native();
    let q = run_builtin("quickstart", &coord);
    assert_eq!(q.rows.len(), 4);
    let g = run_builtin("gemm-roofline", &coord);
    assert_eq!(g.rows.len(), 4);
    assert!(g.cell("MP1_DP512", "Total_s").unwrap() > 0.0);
}

// ---- error paths ----------------------------------------------------------

#[test]
fn malformed_specs_fail_loudly() {
    // TOML syntax error.
    assert!(ScenarioSpec::parse_str("name = \n").is_err());
    // Unknown study kind.
    assert!(ScenarioSpec::parse_str(
        "name = \"x\"\n[study]\nkind = \"frobnicate\"\n"
    )
    .is_err());
    // Unknown key (typo'd axis name).
    assert!(ScenarioSpec::parse_str(
        "name = \"x\"\n[study]\nkind = \"grid\"\nem_bandwidth_gbps = [1]\n"
    )
    .is_err());
    // Strategy label garbage.
    assert!(ScenarioSpec::parse_str(
        "name = \"x\"\n[study]\nkind = \"grid\"\nstrategies = [\"8x128\"]\n"
    )
    .is_err());
    // Cluster that fails validation (non-power-of-two).
    assert!(ScenarioSpec::parse_str(
        "name = \"x\"\n[cluster]\npreset = \"baseline\"\nn_nodes = 1000\n\
         [study]\nkind = \"grid\"\n"
    )
    .is_err());
}

#[test]
fn run_rejects_inconsistent_specs() {
    let coord = Coordinator::native();
    // Speedup without a baseline.
    let spec = ScenarioSpec::parse_str(
        "name = \"x\"\n[study]\nkind = \"grid\"\n\
         strategies = [\"MP8_DP128\"]\nem_bandwidths_gbps = [500]\n\
         [output]\ncontent = \"speedup\"\n",
    )
    .unwrap();
    assert!(run(&spec, &coord).is_err());
    // DLRM study with a transformer workload.
    let spec = ScenarioSpec::parse_str(
        "name = \"x\"\n[study]\nkind = \"packing\"\npackings = [8]\n\
         em_bandwidths_gbps = [500]\n",
    )
    .unwrap();
    assert!(run(&spec, &coord).is_err());
}
