//! Property-based tests over randomized inputs (deterministic PRNG — the
//! offline crate set has no proptest, so comet::util::prng drives the
//! generation; every case count is fixed and seeds are printed on failure).

use comet::analytical::{evaluate, goodput};
use comet::compute::{gemm_traffic, hybrid_bandwidth};
use comet::config::{presets, MAX_TIERS};
use comet::coordinator::Coordinator;
use comet::model::inputs::{decompose, derive_inputs, resolve_inputs, EvalOptions};
use comet::network::{
    collective_cost, collective_cost_tiered, CollectiveImpl, CollectiveSpec,
};
use comet::optimizer::{checkpoint::Checkpoint, Outcome, SearchExec};
use comet::parallel::{model_state_bytes, PipeSchedule, Strategy, ZeroStage};
use comet::resilience::{checkpoint_bandwidth, FaultModel};
use comet::scenario::{optimizer_for, ScenarioSpec};
use comet::sim::{
    simulate, simulate_goodput, simulate_goodput_oracle, simulate_oracle,
    CalendarQueue, Event, EventQueue, Scheduler, TierLinks,
};
use comet::util::cancel::RunControl;
use comet::util::prng::Rng;
use comet::util::stats::rel_diff;
use comet::workload::dlrm::Dlrm;
use comet::workload::trace;
use comet::workload::transformer::Transformer;
use comet::workload::Collective;

const CASES: usize = 200;

#[test]
fn traffic_monotone_in_buffer_and_bounded_below() {
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let u = rng.log_range(1.0, 1e12);
        let v = rng.log_range(1.0, 1e12);
        let w = rng.log_range(1.0, 1e12);
        let s1 = rng.log_range(1e6, 1e11);
        let s2 = s1 * rng.range(1.0, 100.0);
        let t1 = gemm_traffic(u, v, w, s1);
        let t2 = gemm_traffic(u, v, w, s2);
        assert!(t2 <= t1 + 1e-6, "case {case}: bigger buffer more traffic");
        assert!(t1 >= u + v + w - 1e-6, "case {case}: below lower bound");
    }
}

#[test]
fn hybrid_bandwidth_between_levels() {
    let mut rng = Rng::new(202);
    for case in 0..CASES {
        let bw_lm = rng.log_range(1e11, 1e13);
        let bw_em = rng.log_range(1e10, bw_lm);
        let frac = rng.f64();
        let bw = hybrid_bandwidth(bw_lm, bw_em, frac);
        assert!(
            bw <= bw_lm + 1e-3 && bw >= bw_em - 1e-3,
            "case {case}: {bw} outside [{bw_em}, {bw_lm}]"
        );
    }
}

#[test]
fn collective_cost_invariants() {
    let mut rng = Rng::new(303);
    let types = [
        Collective::AllReduce,
        Collective::AllToAll,
        Collective::AllGather,
        Collective::ReduceScatter,
    ];
    for case in 0..CASES {
        let spec = CollectiveSpec::two_level(
            *rng.choose(&types),
            rng.log_range(1e3, 1e12),
            rng.pow2(0, 5) as usize,
            rng.pow2(0, 7) as usize,
        );
        let bwi = rng.log_range(1e10, 1e12);
        let bwx = rng.log_range(1e9, bwi);
        let lat = rng.range(0.0, 1e-5);
        for impl_ in [CollectiveImpl::LogicalRing, CollectiveImpl::Hierarchical]
        {
            let c = collective_cost(&spec, bwi, bwx, lat, impl_);
            assert!(c.is_finite() && c >= 0.0, "case {case}");
            // More bytes never cheaper.
            let spec2 = CollectiveSpec {
                bytes: spec.bytes * 2.0,
                ..spec
            };
            assert!(
                collective_cost(&spec2, bwi, bwx, lat, impl_) >= c - 1e-12,
                "case {case}: bytes monotonicity ({impl_:?})"
            );
            // More bandwidth never slower.
            assert!(
                collective_cost(&spec, bwi * 2.0, bwx * 2.0, lat, impl_)
                    <= c + 1e-12,
                "case {case}: bandwidth monotonicity ({impl_:?})"
            );
        }
        // Hierarchical never loses to a flat ring for multi-pod all-reduce
        // when the inter-pod links are the slower class.
        if spec.collective == Collective::AllReduce
            && spec.n_inter > 1
            && spec.n_intra > 1
        {
            let h = collective_cost(
                &spec,
                bwi,
                bwx,
                0.0,
                CollectiveImpl::Hierarchical,
            );
            let r = collective_cost(
                &spec,
                bwi,
                bwx,
                0.0,
                CollectiveImpl::LogicalRing,
            );
            assert!(h <= r * 1.001, "case {case}: hier {h} vs ring {r}");
        }
    }
}

#[test]
fn tiered_collective_costs_finite_positive_and_monotone() {
    // Randomized N-tier chains x collectives: costs stay finite and
    // non-negative, doubling the payload never gets cheaper, and raising
    // any single tier's bandwidth never makes a collective slower.
    let mut rng = Rng::new(1717);
    let types = [
        Collective::AllReduce,
        Collective::AllToAll,
        Collective::AllGather,
        Collective::ReduceScatter,
    ];
    for case in 0..CASES {
        let k = 1 + rng.below(MAX_TIERS);
        let mut tier_n = [1usize; MAX_TIERS];
        for t in tier_n.iter_mut().take(k) {
            *t = rng.pow2(0, 3) as usize;
        }
        let spec = CollectiveSpec::tiered(
            *rng.choose(&types),
            rng.log_range(1e3, 1e12),
            tier_n,
            k,
        );
        let mut bw = [1.0f64; MAX_TIERS];
        let mut lat = [0.0f64; MAX_TIERS];
        bw[0] = rng.log_range(1e10, 1e12);
        lat[0] = rng.range(0.0, 1e-5);
        for t in 1..k {
            bw[t] = bw[t - 1] / rng.range(1.0, 16.0);
            lat[t] = lat[t - 1] * rng.range(1.0, 4.0);
        }
        for impl_ in [CollectiveImpl::LogicalRing, CollectiveImpl::Hierarchical]
        {
            let c = collective_cost_tiered(&spec, &bw, &lat, impl_);
            assert!(c.is_finite() && c >= 0.0, "case {case}: {c}");
            if spec.n() > 1 {
                assert!(c > 0.0, "case {case}: free op over {} nodes", spec.n());
            }
            let bigger = CollectiveSpec::tiered(
                spec.collective,
                spec.bytes * 2.0,
                tier_n,
                k,
            );
            assert!(
                collective_cost_tiered(&bigger, &bw, &lat, impl_) >= c - 1e-12,
                "case {case}: bytes monotonicity ({impl_:?})"
            );
            for t in 0..k {
                let mut faster = bw;
                faster[t] *= rng.range(1.5, 8.0);
                let c2 = collective_cost_tiered(&spec, &faster, &lat, impl_);
                assert!(
                    c2 <= c + 1e-12,
                    "case {case} tier {t} ({impl_:?}): {c2} > {c}"
                );
            }
        }
    }
}

#[test]
fn two_tier_chain_bit_identical_to_legacy_two_level() {
    // The lowering contract behind every figure pin, randomized: a
    // 2-tier chain must cost bit-for-bit what the legacy two-level view
    // costs, for every collective, implementation, and group shape.
    let mut rng = Rng::new(1818);
    let types = [
        Collective::AllReduce,
        Collective::AllToAll,
        Collective::AllGather,
        Collective::ReduceScatter,
    ];
    for case in 0..CASES {
        let ni = rng.pow2(0, 5) as usize;
        let nx = rng.pow2(0, 6) as usize;
        let bytes = rng.log_range(1e3, 1e12);
        let bwi = rng.log_range(1e10, 1e12);
        let bwx = rng.log_range(1e9, bwi);
        let lat = rng.range(0.0, 1e-5);
        let coll = *rng.choose(&types);
        let legacy = CollectiveSpec::two_level(coll, bytes, ni, nx);
        let tiered = CollectiveSpec::tiered(coll, bytes, [ni, nx, 1, 1], 2);
        let bw = [bwi, bwx, 0.0, 0.0];
        let lats = [lat; MAX_TIERS];
        for impl_ in [CollectiveImpl::LogicalRing, CollectiveImpl::Hierarchical]
        {
            let a = collective_cost(&legacy, bwi, bwx, lat, impl_);
            let b = collective_cost_tiered(&tiered, &bw, &lats, impl_);
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "case {case} {coll:?} {impl_:?} {ni}x{nx}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn collapsing_equal_bandwidth_adjacent_tiers_preserves_cost() {
    // With zero latency, two adjacent tiers sharing one bandwidth are
    // indistinguishable from a single tier holding their product: the
    // ring byte terms telescope ((n0-1)/n0 + (n1-1)/(n0*n1) =
    // (n0*n1-1)/(n0*n1)). Latency terms do not collapse — a merged ring
    // takes n0*n1-1 hops vs (n0-1)+(n1-1) — and all-to-all re-buckets
    // peer fractions, so both stay out of scope.
    let mut rng = Rng::new(1919);
    let types = [
        Collective::AllReduce,
        Collective::AllGather,
        Collective::ReduceScatter,
    ];
    let k = 3;
    for case in 0..CASES {
        let mut tier_n = [1usize; MAX_TIERS];
        for t in tier_n.iter_mut().take(k) {
            *t = rng.pow2(0, 3) as usize;
        }
        let j = rng.below(k - 1); // merge tiers j and j+1
        let mut bw = [1.0f64; MAX_TIERS];
        bw[0] = rng.log_range(1e10, 1e12);
        for t in 1..k {
            bw[t] = bw[t - 1] / rng.range(1.0, 8.0);
        }
        bw[j + 1] = bw[j];
        let lat = [0.0f64; MAX_TIERS];
        let bytes = rng.log_range(1e3, 1e12);

        let mut merged_n = [1usize; MAX_TIERS];
        let mut merged_bw = [1.0f64; MAX_TIERS];
        let (mut m, mut t) = (0, 0);
        while t < k {
            if t == j {
                merged_n[m] = tier_n[j] * tier_n[j + 1];
                merged_bw[m] = bw[j];
                t += 2;
            } else {
                merged_n[m] = tier_n[t];
                merged_bw[m] = bw[t];
                t += 1;
            }
            m += 1;
        }
        let coll = *rng.choose(&types);
        for impl_ in [CollectiveImpl::LogicalRing, CollectiveImpl::Hierarchical]
        {
            let a = collective_cost_tiered(
                &CollectiveSpec::tiered(coll, bytes, tier_n, k),
                &bw,
                &lat,
                impl_,
            );
            let b = collective_cost_tiered(
                &CollectiveSpec::tiered(coll, bytes, merged_n, k - 1),
                &merged_bw,
                &lat,
                impl_,
            );
            if a == 0.0 {
                assert_eq!(b, 0.0, "case {case} {coll:?} {impl_:?} j={j}");
            } else {
                assert!(
                    ((a - b) / a).abs() < 1e-12,
                    "case {case} {coll:?} {impl_:?} j={j}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn tiered_closed_form_matches_event_driven_ring_sim() {
    // Oracle cross-check: the tiered hierarchical closed form vs an
    // event-by-event per-tier ring execution on the DES FIFO link
    // resources. Each ring pass becomes n-1 discrete transfers of one
    // shard-slice each (1 latency hop per step); phases chain on
    // completion, exactly how the two-level DES schedules collectives.
    let mut rng = Rng::new(2020);
    let k = 3;
    for case in 0..60 {
        let mut tier_n = [1usize; MAX_TIERS];
        for t in tier_n.iter_mut().take(k) {
            *t = *rng.choose(&[2usize, 4, 8]);
        }
        let mut bw = [1.0f64; MAX_TIERS];
        let mut lat = [0.0f64; MAX_TIERS];
        bw[0] = rng.log_range(1e10, 1e12);
        lat[0] = rng.range(1e-7, 1e-5);
        for t in 1..k {
            bw[t] = bw[t - 1] / rng.range(2.0, 16.0);
            lat[t] = lat[t - 1] * rng.range(1.0, 4.0);
        }
        let bytes = rng.log_range(1e6, 1e11);
        for coll in [
            Collective::AllReduce,
            Collective::AllGather,
            Collective::ReduceScatter,
        ] {
            let spec = CollectiveSpec::tiered(coll, bytes, tier_n, k);
            let want = collective_cost_tiered(
                &spec,
                &bw,
                &lat,
                CollectiveImpl::Hierarchical,
            );
            let pairs: Vec<(f64, f64)> =
                (0..k).map(|t| (bw[t], lat[t])).collect();
            let mut links = TierLinks::new(&pairs);
            let mut shard = [0.0f64; MAX_TIERS];
            let mut b = bytes;
            for t in 0..k {
                shard[t] = b;
                b /= tier_n[t] as f64;
            }
            // Hierarchical schedule: reduce-scatter up the chain, a full
            // all-reduce ring at the top (AR only), all-gather back down;
            // half collectives make one pass per tier.
            let mut passes: Vec<usize> = Vec::new();
            match coll {
                Collective::AllReduce => {
                    passes.extend(0..k - 1);
                    passes.push(k - 1);
                    passes.push(k - 1);
                    passes.extend((0..k - 1).rev());
                }
                _ => passes.extend(0..k),
            }
            let mut now = 0.0;
            for &t in &passes {
                let n = tier_n[t];
                let step = shard[t] / n as f64;
                for _ in 0..n - 1 {
                    now = links.transfer(t, now, step, 1);
                }
            }
            assert!(
                rel_diff(want, now) < 1e-9,
                "case {case} {coll:?}: closed {want} vs sim {now}"
            );
        }
    }
}

#[test]
fn tiered_heterogeneous_search_matches_exhaustive_across_threads() {
    // Optimizer exactness on the heterogeneous 3-tier lattice: branch
    // and bound — sequential and parallel at 2 and 8 threads — must
    // return the exhaustive argmin/top-k/frontier and exact counters
    // bit-for-bit on the tiered-het-64 preset, where per-tier collective
    // costs and group-scaled node parameters shape every leaf.
    let mut rng = Rng::new(2121);
    let coord = Coordinator::native().with_threads(8);
    for case in 0..6 {
        let max_pp = *rng.choose(&[1usize, 2]);
        let min_mp = *rng.choose(&[1usize, 2]);
        let max_mp = *rng.choose(&[8usize, 16, 32]);
        let top_k = 1 + rng.below(4);
        let mut doc = format!(
            "name = \"opt-tiered-{case}\"\n\
             [workload]\nkind = \"transformer\"\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"tiered-het-64\"\n\
             [study]\nkind = \"optimize\"\nmin_mp = {min_mp}\n\
             max_mp = {max_mp}\nmax_pp = {max_pp}\ntop_k = {top_k}\n"
        );
        if rng.f64() < 0.7 {
            doc.push_str("em_bandwidths_gbps = [500, 2039]\n");
        }
        if rng.f64() < 0.5 {
            doc.push_str("collectives = [\"ring\", \"hierarchical\"]\n");
        }
        if rng.f64() < 0.4 {
            doc.push_str("zero_stages = [0, 2, 3]\n");
        }
        if rng.f64() < 0.5 {
            doc.push_str("[options]\ninfinite_memory = true\n");
        }
        let spec = ScenarioSpec::parse_str(&doc).unwrap();
        let opt = optimizer_for(&spec, &coord).unwrap();
        let e = opt.exhaustive().unwrap();
        let seq = opt.search_parallel(1).unwrap();
        for threads in [2usize, 8] {
            let par = opt.search_parallel(threads).unwrap();
            seq.assert_bit_identical(&par, &format!("case {case} t{threads}"));
        }
        assert_eq!(seq.top.len(), e.top.len(), "case {case}");
        for (a, b) in seq.top.iter().zip(&e.top) {
            assert_eq!(a.label, b.label, "case {case}");
            assert_eq!(a.point.index, b.point.index, "case {case}");
            assert_eq!(
                a.total().to_bits(),
                b.total().to_bits(),
                "case {case}: {}",
                a.label
            );
        }
        assert_eq!(seq.infeasible, e.infeasible, "case {case}");
        assert_eq!(seq.evaluated + seq.pruned, e.evaluated, "case {case}");
        for out in [&seq, &e] {
            assert_eq!(
                out.evaluated + out.pruned + out.infeasible,
                out.total_points,
                "case {case}"
            );
        }
        for c in seq.top.iter().chain(&seq.frontier) {
            assert!(
                c.lower_bound <= c.total(),
                "case {case}: {} bound {} > total {}",
                c.label,
                c.lower_bound,
                c.total()
            );
        }
    }
}

#[test]
fn zero_footprint_ordering_random_splits() {
    let mut rng = Rng::new(404);
    for case in 0..CASES {
        let psi = rng.log_range(1e9, 1e13);
        let mp = rng.pow2(0, 10) as usize;
        let dp = rng.pow2(0, 10) as usize;
        let b = model_state_bytes(psi, mp, dp, ZeroStage::Baseline);
        let z1 = model_state_bytes(psi, mp, dp, ZeroStage::Os);
        let z2 = model_state_bytes(psi, mp, dp, ZeroStage::OsG);
        let z3 = model_state_bytes(psi, mp, dp, ZeroStage::OsGP);
        assert!(b >= z1 && z1 >= z2 && z2 >= z3, "case {case}");
        // DP=1 collapses all stages to baseline.
        if dp == 1 {
            assert!(rel_diff(b, z3) < 1e-12, "case {case}");
        }
    }
}

#[test]
fn strategy_label_roundtrip_random_2d_and_3d() {
    let mut rng = Rng::new(1010);
    for case in 0..CASES {
        let mp = rng.pow2(0, 10) as usize;
        let dp = rng.pow2(0, 10) as usize;
        let pp = rng.pow2(0, 6) as usize;
        let s = if rng.f64() < 0.5 {
            Strategy::new(mp, dp).unwrap()
        } else {
            Strategy::new_3d(mp, dp, pp).unwrap()
        };
        assert_eq!(
            Strategy::parse(&s.label()).unwrap(),
            s,
            "case {case}: {}",
            s.label()
        );
        // Malformed variants of the same label must be rejected: zero
        // degrees, trailing garbage, and PP0.
        assert!(Strategy::parse(&format!("MP0_DP{dp}")).is_err());
        assert!(Strategy::parse(&format!("MP{mp}_DP0")).is_err());
        assert!(Strategy::parse(&format!("MP{mp}_DP{dp}_PP0")).is_err());
        assert!(Strategy::parse(&format!("MP{mp}_DP{dp}x")).is_err());
        assert!(Strategy::parse(&format!("MP{mp}_DP{dp}_PP{pp}y")).is_err());
        assert!(Strategy::parse(&format!("MP{mp}_DP{dp}_PP")).is_err());
        assert!(Strategy::parse(&format!(" MP{mp}_DP{dp}")).is_err());
    }
}

#[test]
fn calendar_queue_matches_heap_on_random_schedules() {
    // The tentpole determinism pin, randomized: under arbitrary bucket
    // geometries (widths spanning eleven orders of magnitude, 1..=257
    // buckets, so events land in-window, far past the horizon, and in
    // rotated slots) and interleaved schedule/pop/pop_batch traffic
    // with forced equal-time ties, the calendar queue must replay the
    // heap queue's (time, seq) FIFO stream exactly — times compared by
    // to_bits, payloads and batch boundaries verbatim.
    fn same(case: usize, a: &Event<u32>, b: &Event<u32>) {
        assert_eq!(
            a.time.to_bits(),
            b.time.to_bits(),
            "case {case}: time {} vs {}",
            a.time,
            b.time
        );
        assert_eq!(a.seq, b.seq, "case {case}");
        assert_eq!(a.payload, b.payload, "case {case}");
    }
    let mut rng = Rng::new(8181);
    for case in 0..CASES {
        let width = rng.log_range(1e-9, 1e2);
        let nbuckets = 1 + rng.below(257);
        let mut cal: CalendarQueue<u32> =
            CalendarQueue::with_geometry(width, nbuckets);
        let mut heap: EventQueue<u32> = EventQueue::new();
        let mut times: Vec<f64> = Vec::new();
        let (mut ba, mut bb) = (Vec::new(), Vec::new());
        let mut payload = 0u32;
        for _op in 0..300 {
            match rng.below(4) {
                0 | 1 => {
                    for _ in 0..1 + rng.below(3) {
                        // Half the time reuse a pending timestamp to
                        // force an equal-time FIFO tie; skip reused
                        // times the mirrored pops have already passed.
                        let t = if !times.is_empty() && rng.f64() < 0.5 {
                            *rng.choose(&times)
                        } else {
                            cal.now() + rng.log_range(1e-12, 1e3)
                        };
                        if t < cal.now() {
                            continue;
                        }
                        cal.schedule(t, payload).unwrap();
                        heap.schedule(t, payload).unwrap();
                        times.push(t);
                        payload += 1;
                    }
                }
                2 => match (cal.pop(), heap.pop()) {
                    (None, None) => {}
                    (Some(a), Some(b)) => same(case, &a, &b),
                    (a, b) => panic!("case {case}: {a:?} vs {b:?}"),
                },
                _ => {
                    let na = cal.pop_batch(&mut ba);
                    let nb = heap.pop_batch(&mut bb);
                    assert_eq!(na, nb, "case {case}: batch sizes");
                    for (a, b) in ba.iter().zip(&bb) {
                        same(case, a, b);
                    }
                }
            }
            assert_eq!(cal.len(), heap.len(), "case {case}");
            assert_eq!(
                cal.now().to_bits(),
                heap.now().to_bits(),
                "case {case}: clocks diverged"
            );
        }
        // Drain the remainder in lockstep.
        loop {
            match (cal.pop(), heap.pop()) {
                (None, None) => break,
                (Some(a), Some(b)) => same(case, &a, &b),
                (a, b) => panic!("case {case}: drain {a:?} vs {b:?}"),
            }
        }
        assert_eq!(cal.peak(), heap.peak(), "case {case}: peak occupancy");
    }
}

#[test]
fn calendar_engine_bitwise_matches_heap_oracle_random_workloads() {
    // End-to-end determinism: the production calendar-queue engine and
    // the retained heap-queue oracle must return identical SimResults
    // (breakdown, event counts, peak occupancy, utilizations) on random
    // strategies across two-level and tiered heterogeneous clusters.
    let mut rng = Rng::new(9292);
    let clusters = [
        presets::dgx_a100_1024(),
        presets::dgx_a100_64(),
        presets::tiered_het_64(),
    ];
    for case in 0..40 {
        let cluster = rng.choose(&clusters).clone();
        let sweep = Strategy::sweep_bounded(cluster.n_nodes, 1, 128).unwrap();
        let s = *rng.choose(&sweep);
        let w = Transformer::t1().build(&s).unwrap();
        let opts = EvalOptions {
            ignore_capacity: rng.f64() < 0.5,
            overlap_wg: rng.f64() < 0.8,
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        let a = simulate(&inp);
        let b = simulate_oracle(&inp);
        assert_eq!(a, b, "case {case} {} on {}", s.label(), cluster.name);
    }
}

#[test]
fn goodput_sim_tracks_analytical_and_heap_oracle_random_renewals() {
    // Goodput-dominated corner on the new engine, randomized: the
    // checkpoint-restart renewal simulation must stay within 8% of the
    // analytical efficiency when the renewal geometry converges (MTBF
    // of 100-400 steps over a 20k-step horizon), and the calendar-queue
    // run must equal the retained heap-queue oracle exactly, trace
    // included.
    let cluster = presets::dgx_a100_1024();
    let mut rng = Rng::new(7373);
    for case in 0..8 {
        let mp = *rng.choose(&[4usize, 8]);
        let s = Strategy::new(mp, 1024 / mp).unwrap();
        let w = Transformer::t1().build(&s).unwrap();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        let step = simulate(&inp).breakdown.total();
        let n = cluster.n_nodes;
        let mut fault = FaultModel::none();
        fault.mtbf_node_hours =
            rng.range(100.0, 400.0) * step * n as f64 / 3600.0;
        fault.restart_s = rng.range(1.0, 10.0) * step;
        fault.seed = 40 + case as u64;
        let ckpt_bw = checkpoint_bandwidth(
            inp.params.bw_inter,
            inp.params.bw_lm,
            inp.params.bw_em,
        );
        let mut inp2 = inp.clone();
        inp2.params.footprint = rng.range(0.5, 4.0) * step * ckpt_bw;
        let des = simulate_goodput(&inp2, &fault, n, 20_000);
        let oracle = simulate_goodput_oracle(&inp2, &fault, n, 20_000);
        assert_eq!(des, oracle, "case {case}: calendar vs heap goodput");
        let g = goodput::analyze(
            &fault,
            n,
            inp2.params.footprint,
            ckpt_bw,
            &simulate(&inp2).breakdown,
        );
        assert!(des.failures > 20, "case {case}: {}", des.failures);
        assert!(
            (des.efficiency - g.efficiency).abs() < 0.08,
            "case {case}: DES {} vs analytical {}",
            des.efficiency,
            g.efficiency
        );
    }
}

#[test]
fn des_tracks_analytical_across_random_pipeline_configs() {
    let mut rng = Rng::new(1111);
    let cluster = presets::dgx_a100_1024();
    for case in 0..30 {
        let pp = *rng.choose(&[2usize, 4, 8]);
        let mp = *rng.choose(&[2usize, 4, 8]);
        let dp = 1024 / (mp * pp);
        let s = Strategy::new_3d(mp, dp, pp).unwrap();
        let w = Transformer::t1().build(&s).unwrap();
        let opts = EvalOptions {
            ignore_capacity: true,
            microbatches: *rng.choose(&[2usize, 4, 8, 16]),
            pipe_schedule: *rng.choose(&PipeSchedule::ALL),
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        let a = evaluate(&inp).total();
        let d = simulate(&inp).breakdown.total();
        assert!(
            rel_diff(a, d) < 0.05,
            "case {case} {} m={}: analytical {a} DES {d}",
            s.label(),
            opts.microbatches
        );
    }
}

#[test]
fn des_tracks_analytical_across_random_configs() {
    let mut rng = Rng::new(505);
    let clusters = [
        presets::dgx_a100_1024(),
        presets::table3_gpu('A', 1),
        presets::table3_gpu('C', 2),
    ];
    for case in 0..60 {
        let cluster = rng.choose(&clusters).clone();
        let sweep = Strategy::sweep_bounded(cluster.n_nodes, 1, 128).unwrap();
        let s = *rng.choose(&sweep);
        let w = Transformer::t1().build(&s).unwrap();
        let opts = EvalOptions {
            ignore_capacity: rng.f64() < 0.5,
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        let a = evaluate(&inp).total();
        let d = simulate(&inp).breakdown.total();
        assert!(
            rel_diff(a, d) < 0.05,
            "case {case} {} on {}: analytical {a} DES {d}",
            s.label(),
            cluster.name
        );
    }
}

#[test]
fn trace_roundtrip_random_workloads() {
    let mut rng = Rng::new(606);
    for case in 0..40 {
        let w = if rng.f64() < 0.5 {
            let n = 1024;
            let sweep = Strategy::sweep_bounded(n, 1, 128).unwrap();
            Transformer::t1().build(rng.choose(&sweep)).unwrap()
        } else {
            Dlrm::dlrm_1_2t()
                .build(*rng.choose(&[8usize, 16, 32, 64]))
                .unwrap()
        };
        let text = trace::emit(&w);
        let back = trace::parse(&text).unwrap();
        assert_eq!(back.layers.len(), w.layers.len(), "case {case}");
        // Re-emitting the parsed trace must be a fixed point.
        assert_eq!(trace::emit(&back), text, "case {case}");
        // And the cost model must agree on both representations.
        let cluster = presets::dgx_a100_1024();
        let opts = EvalOptions {
            footprint_override: Some(100e9),
            ..Default::default()
        };
        let a = evaluate(&derive_inputs(&w, &cluster, &opts).unwrap());
        let b = evaluate(&derive_inputs(&back, &cluster, &opts).unwrap());
        assert!(
            rel_diff(a.total(), b.total()) < 1e-9,
            "case {case}: {} vs {}",
            a.total(),
            b.total()
        );
    }
}

#[test]
fn cluster_json_roundtrip_random_mutations() {
    let mut rng = Rng::new(707);
    for case in 0..CASES {
        let mut c = presets::dgx_a100_1024();
        c.node.perf_peak = rng.log_range(1e12, 1e17);
        c.node.sram = rng.log_range(1e6, 1e11);
        c.node.local.capacity = rng.log_range(1e9, 1e12);
        c.node.local.bandwidth = rng.log_range(1e11, 2e13);
        if rng.f64() < 0.5 {
            c.node.expanded.capacity = rng.log_range(1e9, 1e12);
            c.node.expanded.bandwidth = rng.log_range(1e10, 2e12);
        }
        let back =
            comet::ClusterConfig::from_json(&c.to_json()).expect("roundtrip");
        assert_eq!(c, back, "case {case}");
    }
}

/// Run an optimize scenario both ways and require identical rankings
/// plus admissible bounds; returns (search, exhaustive).
fn search_vs_exhaustive(doc: &str) -> (Outcome, Outcome) {
    let spec = ScenarioSpec::parse_str(doc).unwrap();
    let coord = Coordinator::native();
    let opt = optimizer_for(&spec, &coord).unwrap();
    let s = opt.search().unwrap();
    let e = opt.exhaustive().unwrap();
    assert_eq!(s.top.len(), e.top.len(), "{}", spec.name);
    for (a, b) in s.top.iter().zip(&e.top) {
        assert_eq!(a.point.index, b.point.index, "{}", spec.name);
        assert_eq!(a.label, b.label, "{}", spec.name);
        assert_eq!(
            a.total().to_bits(),
            b.total().to_bits(),
            "{}: {}",
            spec.name,
            a.label
        );
    }
    // Admissibility: every reported lower bound <= the evaluated cost.
    for c in s.top.iter().chain(&s.frontier).chain(&e.frontier) {
        assert!(
            c.lower_bound <= c.total(),
            "{}: {} bound {} > total {}",
            spec.name,
            c.label,
            c.lower_bound,
            c.total()
        );
    }
    assert_eq!(s.infeasible, e.infeasible);
    assert_eq!(s.evaluated + s.pruned, e.evaluated);
    (s, e)
}

#[test]
fn optimizer_matches_exhaustive_transformer_small_space() {
    // Transformer-1T on a 64-node slice: every strategy spills, so the
    // 2x2 (bandwidth x collective) axes genuinely move the totals.
    let (s, e) = search_vs_exhaustive(
        "name = \"opt-prop-tf\"\n\
         [workload]\nkind = \"transformer\"\npreset = \"transformer-1t\"\n\
         [cluster]\npreset = \"baseline\"\nn_nodes = 64\n\
         [study]\nkind = \"optimize\"\nmin_mp = 8\nmax_mp = 32\n\
         em_bandwidths_gbps = [500, 2039]\n\
         collectives = [\"ring\", \"hierarchical\"]\ntop_k = 3\n",
    );
    assert_eq!(e.total_points, 3 * 2 * 2);
    assert_eq!(e.evaluated, 12);
    assert!(s.evaluated <= e.evaluated);
}

#[test]
fn optimizer_matches_exhaustive_transformer_zero_axis() {
    // ZeRO stage as a search axis (stage-3 pays its 1.5x DP volume).
    let (s, e) = search_vs_exhaustive(
        "name = \"opt-prop-zero\"\n\
         [workload]\nkind = \"transformer\"\npreset = \"transformer-100m\"\n\
         [cluster]\npreset = \"dgx-a100-64\"\n\
         [study]\nkind = \"optimize\"\nmin_mp = 1\nmax_mp = 8\n\
         zero_stages = [0, 2, 3]\ntop_k = 4\n\
         [options]\ninfinite_memory = true\n",
    );
    assert_eq!(e.total_points, 4 * 3);
    assert!(s.evaluated <= e.evaluated);
}

#[test]
fn optimizer_matches_exhaustive_dlrm_small_space() {
    // DLRM's rigid parallelism: a single branch, 2x2 memory axes, with
    // the 40 GB capacity column infeasible (cannot hold the 70 GB
    // spill). Ties across capacities break by lattice order — identical
    // in both modes.
    let (s, e) = search_vs_exhaustive(
        "name = \"opt-prop-dlrm\"\n\
         [workload]\nkind = \"dlrm\"\npreset = \"dlrm-1.2t\"\n\
         [cluster]\npreset = \"dgx-a100-64\"\nn_nodes = 16\n\
         [study]\nkind = \"optimize\"\n\
         em_bandwidths_gbps = [500, 2039]\n\
         em_capacities_gb = [40, 160]\ntop_k = 2\n",
    );
    assert_eq!(e.total_points, 4);
    assert_eq!(e.infeasible, 2);
    assert_eq!(e.evaluated, 2);
    assert!(s.evaluated <= 2);
    // Higher EM bandwidth can never lose on a spilled shard.
    assert!(s.top[0].label.contains("2039"), "{}", s.top[0].label);
}

#[test]
fn parallel_search_matches_sequential_and_exhaustive_random_lattices() {
    // The parallel driver's headline guarantee, exercised over
    // randomized 2D and 3D optimize lattices: at every thread count the
    // full Outcome — argmin label, top-k order, Pareto frontier, and the
    // exact evaluated/pruned/infeasible counters — is bit-identical to
    // the sequential driver, and the top-k is bit-identical to the
    // exhaustive oracle (ties broken by canonical lattice index).
    let mut rng = Rng::new(4242);
    let coord = Coordinator::native().with_threads(8);
    for case in 0..10 {
        let max_pp = *rng.choose(&[1usize, 2, 4]);
        let min_mp = *rng.choose(&[1usize, 2]);
        let max_mp = *rng.choose(&[4usize, 8]);
        let top_k = 1 + rng.below(4);
        let mut doc = format!(
            "name = \"opt-rand-{case}\"\n\
             [workload]\nkind = \"transformer\"\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"optimize\"\nmin_mp = {min_mp}\n\
             max_mp = {max_mp}\nmax_pp = {max_pp}\ntop_k = {top_k}\n"
        );
        let with_bw = rng.f64() < 0.7;
        if with_bw {
            doc.push_str(*rng.choose(&[
                "em_bandwidths_gbps = [500, 2039]\n",
                "em_bandwidths_gbps = [250, 1000, 2039]\n",
            ]));
            if rng.f64() < 0.5 {
                doc.push_str("em_capacities_gb = [40, 400]\n");
            }
        }
        if rng.f64() < 0.5 {
            doc.push_str("collectives = [\"ring\", \"hierarchical\"]\n");
        }
        if rng.f64() < 0.4 {
            doc.push_str("zero_stages = [0, 2, 3]\n");
        }
        if rng.f64() < 0.5 {
            doc.push_str("[options]\ninfinite_memory = true\n");
        }
        let spec = ScenarioSpec::parse_str(&doc).unwrap();
        let opt = optimizer_for(&spec, &coord).unwrap();
        let e = opt.exhaustive().unwrap();
        let seq = opt.search_parallel(1).unwrap();
        for threads in [2usize, 8] {
            let par = opt.search_parallel(threads).unwrap();
            // Everything, bit-for-bit (shared checker — same strictness
            // as the unit tests and bench_optimizer).
            seq.assert_bit_identical(&par, &format!("case {case} t{threads}"));
        }
        // The search (any width) returns the exhaustive top-k exactly.
        assert_eq!(seq.top.len(), e.top.len(), "case {case}");
        for (a, b) in seq.top.iter().zip(&e.top) {
            assert_eq!(a.label, b.label, "case {case}");
            assert_eq!(a.point.index, b.point.index, "case {case}");
            assert_eq!(
                a.total().to_bits(),
                b.total().to_bits(),
                "case {case}: {}",
                a.label
            );
        }
        assert_eq!(seq.infeasible, e.infeasible, "case {case}");
        assert_eq!(seq.evaluated + seq.pruned, e.evaluated, "case {case}");
        // Counters partition the lattice in every driver.
        for out in [&seq, &e] {
            assert_eq!(
                out.evaluated + out.pruned + out.infeasible,
                out.total_points,
                "case {case}"
            );
        }
        // Admissibility of every reported bound.
        for c in seq.top.iter().chain(&seq.frontier) {
            assert!(
                c.lower_bound <= c.total(),
                "case {case}: {} bound {} > total {}",
                c.label,
                c.lower_bound,
                c.total()
            );
        }
    }
}

#[test]
fn cancel_checkpoint_resume_bit_identical_random_lattices() {
    // The execution-robustness headline guarantee, randomized: cancel a
    // search at an arbitrary safe boundary, flush the checkpoint,
    // resume (repeatedly — each hop may be cancelled again), and the
    // final Outcome must be bit-identical — argmin, top-k, frontier,
    // AND the evaluated/pruned/infeasible/remaining counters — to an
    // uninterrupted run, at every thread count. Every partial hop must
    // also keep the partial counter partition exact.
    let mut rng = Rng::new(6464);
    let coord = Coordinator::native().with_threads(8);
    let dir = std::env::temp_dir();
    for case in 0..6 {
        let max_pp = *rng.choose(&[1usize, 2, 4]);
        let min_mp = *rng.choose(&[1usize, 2]);
        let max_mp = *rng.choose(&[4usize, 8]);
        let top_k = 1 + rng.below(4);
        let mut doc = format!(
            "name = \"resume-rand-{case}\"\n\
             [workload]\nkind = \"transformer\"\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [study]\nkind = \"optimize\"\nmin_mp = {min_mp}\n\
             max_mp = {max_mp}\nmax_pp = {max_pp}\ntop_k = {top_k}\n"
        );
        if rng.f64() < 0.7 {
            doc.push_str("em_bandwidths_gbps = [500, 2039]\n");
        }
        if rng.f64() < 0.5 {
            doc.push_str("collectives = [\"ring\", \"hierarchical\"]\n");
        }
        if rng.f64() < 0.5 {
            doc.push_str("[options]\ninfinite_memory = true\n");
        }
        let spec = ScenarioSpec::parse_str(&doc).unwrap();
        let opt = optimizer_for(&spec, &coord).unwrap();
        let oracle = opt.search_sequential().unwrap();
        assert!(
            oracle.complete && oracle.remaining == 0 && oracle.stop.is_none(),
            "case {case}: oracle not complete"
        );
        for threads in [1usize, 2, 8] {
            let path = dir.join(format!(
                "comet-prop-ck-{}-{case}-{threads}.json",
                std::process::id()
            ));
            let mut resume: Option<Checkpoint> = None;
            let mut hops = 0usize;
            let out = loop {
                hops += 1;
                // >= 1 poll per hop guarantees progress, so the chain
                // terminates; 200 hops is far beyond any lattice here.
                assert!(hops <= 200, "case {case} t{threads}: no progress");
                let polls = 1 + rng.below(9) as u64;
                let mut exec = SearchExec::default()
                    .with_control(
                        RunControl::unbounded().cancel_after_polls(polls),
                    )
                    .with_checkpoint(path.clone());
                if let Some(ck) = resume.take() {
                    exec = exec.with_resume(ck);
                }
                let out = opt.search_parallel_with(threads, &exec).unwrap();
                if out.complete {
                    break out;
                }
                assert!(out.stop.is_some(), "case {case} t{threads}");
                assert_eq!(out.pruned, 0, "case {case} t{threads}");
                assert_eq!(
                    out.evaluated + out.infeasible + out.remaining,
                    out.total_points,
                    "case {case} t{threads}: partial partition"
                );
                resume = Some(Checkpoint::load(&path).unwrap());
            };
            oracle.assert_bit_identical(
                &out,
                &format!("case {case} t{threads} hops={hops}"),
            );
            let _ = std::fs::remove_file(&path);
        }
    }
}

#[test]
fn evaluate_and_goodput_never_nan_on_random_valid_configs() {
    // Robustness contract: any cluster that passes `validate()` combined
    // with any fault model that passes `FaultModel::validate()` yields
    // finite costs through the whole stack — evaluator, goodput
    // efficiency model, and effective time — never NaN or ±inf.
    let mut rng = Rng::new(6161);
    for case in 0..60 {
        let mut c = presets::dgx_a100_1024();
        c.node.perf_peak = rng.log_range(1e12, 1e17);
        c.node.sram = rng.log_range(1e6, 1e11);
        c.node.local.capacity = rng.log_range(1e10, 1e12);
        c.node.local.bandwidth = rng.log_range(1e11, 2e13);
        if rng.f64() < 0.5 {
            c.node.expanded.capacity = rng.log_range(1e9, 1e12);
            c.node.expanded.bandwidth = rng.log_range(1e10, 2e12);
        }
        c.validate().expect("generator must emit valid clusters");
        let sweep = Strategy::sweep_bounded(c.n_nodes, 1, 128).unwrap();
        let s = *rng.choose(&sweep);
        let w = Transformer::t1().build(&s).unwrap();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let b = evaluate(&derive_inputs(&w, &c, &opts).unwrap());
        assert!(
            b.total().is_finite() && b.total() > 0.0,
            "case {case}: total {}",
            b.total()
        );

        let fault = FaultModel {
            mtbf_node_hours: if rng.f64() < 0.2 {
                f64::INFINITY
            } else {
                rng.log_range(1.0, 1e7)
            },
            restart_s: rng.range(0.0, 3600.0),
            straggler_frac: rng.range(0.0, 0.2),
            straggler_slowdown: rng.range(1.0, 4.0),
            link_degrade_frac: rng.range(0.0, 0.2),
            link_degrade_factor: rng.range(1.0, 4.0),
            seed: case as u64,
        };
        fault.validate().expect("generator must emit valid fault models");
        let ckpt_bw = checkpoint_bandwidth(
            rng.log_range(1e9, 1e12),
            c.node.local.bandwidth,
            c.node.expanded.bandwidth,
        );
        let g = goodput::analyze(
            &fault,
            c.n_nodes,
            rng.log_range(1e9, 1e13),
            ckpt_bw,
            &b,
        );
        assert!(
            g.efficiency.is_finite()
                && g.efficiency > 0.0
                && g.efficiency <= 1.0,
            "case {case}: efficiency {}",
            g.efficiency
        );
        assert!(
            g.ckpt_write_s.is_finite() && g.ckpt_write_s >= 0.0,
            "case {case}: ckpt_write_s {}",
            g.ckpt_write_s
        );
        let t = g.effective_time(b.total());
        assert!(
            t.is_finite() && t >= b.total(),
            "case {case}: effective {t} vs total {}",
            b.total()
        );
    }
}

#[test]
fn goodput_search_matches_exhaustive_random_lattices_across_threads() {
    // The resilience counterpart of the random-lattice bit-identity test:
    // with a fault model attached and the goodput objective selected,
    // every thread count must still return the exhaustive argmin/top-k
    // bit-for-bit, the counters must still partition the lattice, and
    // the admissibility chain `bound <= total <= score` must hold for
    // every reported candidate (the score divides the total by an
    // efficiency in (0, 1], so the fault-free bound stays admissible).
    let mut rng = Rng::new(5353);
    let coord = Coordinator::native().with_threads(8);
    for case in 0..8 {
        let max_pp = *rng.choose(&[1usize, 2]);
        let max_mp = *rng.choose(&[4usize, 8]);
        let top_k = 1 + rng.below(4);
        let mtbf = *rng.choose(&[50.0f64, 500.0, 5000.0]);
        let frac = *rng.choose(&[0.0f64, 0.02]);
        let mut doc = format!(
            "name = \"goodput-rand-{case}\"\n\
             [workload]\nkind = \"transformer\"\npreset = \"transformer-100m\"\n\
             [cluster]\npreset = \"dgx-a100-64\"\n\
             [resilience]\nmtbf_node_hours = {mtbf}\nrestart_s = 90\n\
             straggler_frac = {frac}\nstraggler_slowdown = 1.5\n\
             [study]\nkind = \"optimize\"\nobjective = \"goodput\"\n\
             min_mp = 1\nmax_mp = {max_mp}\nmax_pp = {max_pp}\n\
             top_k = {top_k}\n"
        );
        if rng.f64() < 0.6 {
            doc.push_str("em_bandwidths_gbps = [500, 2039]\n");
        }
        if rng.f64() < 0.4 {
            doc.push_str("zero_stages = [0, 2, 3]\n");
        }
        if rng.f64() < 0.5 {
            doc.push_str("[options]\ninfinite_memory = true\n");
        }
        let spec = ScenarioSpec::parse_str(&doc).unwrap();
        let opt = optimizer_for(&spec, &coord).unwrap();
        let e = opt.exhaustive().unwrap();
        let seq = opt.search_parallel(1).unwrap();
        for threads in [2usize, 8] {
            let par = opt.search_parallel(threads).unwrap();
            seq.assert_bit_identical(&par, &format!("case {case} t{threads}"));
        }
        assert_eq!(seq.top.len(), e.top.len(), "case {case}");
        for (a, b) in seq.top.iter().zip(&e.top) {
            assert_eq!(a.label, b.label, "case {case}");
            assert_eq!(a.point.index, b.point.index, "case {case}");
            assert_eq!(
                a.score.to_bits(),
                b.score.to_bits(),
                "case {case}: {}",
                a.label
            );
        }
        assert_eq!(seq.infeasible, e.infeasible, "case {case}");
        assert_eq!(seq.evaluated + seq.pruned, e.evaluated, "case {case}");
        for out in [&seq, &e] {
            assert_eq!(
                out.evaluated + out.pruned + out.infeasible,
                out.total_points,
                "case {case}"
            );
        }
        for c in seq.top.iter().chain(&seq.frontier) {
            assert!(
                c.efficiency > 0.0 && c.efficiency <= 1.0,
                "case {case}: {} efficiency {}",
                c.label,
                c.efficiency
            );
            assert!(
                c.lower_bound <= c.total() && c.total() <= c.score,
                "case {case}: {} bound {} total {} score {}",
                c.label,
                c.lower_bound,
                c.total(),
                c.score
            );
        }
    }
}

#[test]
fn goodput_sim_deterministic_for_random_fault_models() {
    // Same seed, same fault model => the DES checkpoint-restart renewal
    // simulation returns an identical event trace and identical totals,
    // both across back-to-back runs and across threads.
    let mut rng = Rng::new(7272);
    let cluster = presets::dgx_a100_64();
    for case in 0..10 {
        let sweep = Strategy::sweep_bounded(cluster.n_nodes, 1, 64).unwrap();
        let s = *rng.choose(&sweep);
        let w = Transformer::t100m().build(&s).unwrap();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let inp = derive_inputs(&w, &cluster, &opts).unwrap();
        let fault = FaultModel {
            mtbf_node_hours: rng.range(0.5, 100.0),
            restart_s: rng.range(1.0, 300.0),
            straggler_frac: rng.range(0.0, 0.1),
            straggler_slowdown: rng.range(1.0, 3.0),
            seed: 1000 + case as u64,
            ..FaultModel::none()
        };
        let a = simulate_goodput(&inp, &fault, cluster.n_nodes, 2_000);
        let b = simulate_goodput(&inp, &fault, cluster.n_nodes, 2_000);
        assert_eq!(a, b, "case {case}: back-to-back runs diverged");
        let inp2 = inp.clone();
        let n = cluster.n_nodes;
        let c = std::thread::spawn(move || {
            simulate_goodput(&inp2, &fault, n, 2_000)
        })
        .join()
        .unwrap();
        assert_eq!(a, c, "case {case}: cross-thread run diverged");
        assert!(
            a.efficiency.is_finite() && a.efficiency > 0.0,
            "case {case}: efficiency {}",
            a.efficiency
        );
    }
}

#[test]
fn two_stage_derive_matches_single_pass_random_configs() {
    // Randomized spot-check on top of the figure-space equivalence test:
    // decompose+resolve must be bit-identical to single-pass derive for
    // arbitrary option combinations.
    let mut rng = Rng::new(909);
    let clusters = [
        presets::dgx_a100_1024(),
        presets::table3_gpu('B', 1),
        presets::dgx_a100_64(),
    ];
    for case in 0..60 {
        let cluster = rng.choose(&clusters).clone();
        let w = if rng.f64() < 0.7 {
            let sweep = Strategy::sweep_bounded(cluster.n_nodes, 1, 128).unwrap();
            Transformer::t1().build(rng.choose(&sweep)).unwrap()
        } else {
            Dlrm::dlrm_1_2t()
                .build(cluster.n_nodes.min(64))
                .unwrap()
        };
        let opts = EvalOptions {
            zero_stage: *rng.choose(&ZeroStage::ALL),
            ignore_capacity: rng.f64() < 0.3,
            em_frac_override: (rng.f64() < 0.3).then(|| rng.f64()),
            footprint_override: (rng.f64() < 0.3)
                .then(|| rng.log_range(1e9, 1e12)),
            overlap_wg: rng.f64() < 0.8,
            collective_impl: *rng.choose(&[
                CollectiveImpl::LogicalRing,
                CollectiveImpl::Hierarchical,
            ]),
            microbatches: *rng.choose(&[1usize, 2, 8, 32]),
            pipe_schedule: *rng.choose(&PipeSchedule::ALL),
        };
        let single = derive_inputs(&w, &cluster, &opts).unwrap();
        let staged = resolve_inputs(&decompose(&w), &cluster, &opts).unwrap();
        assert_eq!(single, staged, "case {case}");
        assert_eq!(
            single.fingerprint(),
            staged.fingerprint(),
            "case {case}"
        );
    }
}

#[test]
fn faster_clusters_never_slower() {
    // Dominance: scaling any single resource up must not increase the
    // iteration time (checked on random strategies).
    let mut rng = Rng::new(808);
    for case in 0..60 {
        let sweep = Strategy::sweep_bounded(1024, 1, 128).unwrap();
        let s = *rng.choose(&sweep);
        let w = Transformer::t1().build(&s).unwrap();
        let base = presets::dgx_a100_1024();
        let opts = EvalOptions {
            ignore_capacity: true,
            ..Default::default()
        };
        let t0 = evaluate(&derive_inputs(&w, &base, &opts).unwrap()).total();

        let factor = rng.range(1.1, 8.0);
        let mut faster = base.clone();
        match rng.below(3) {
            0 => faster.node.perf_peak *= factor,
            1 => faster.node.local.bandwidth *= factor,
            _ => faster = faster.scale_network(factor, factor),
        }
        let t1 = evaluate(&derive_inputs(&w, &faster, &opts).unwrap()).total();
        assert!(
            t1 <= t0 * (1.0 + 1e-9),
            "case {case} {}: {t0} -> {t1}",
            s.label()
        );
    }
}
